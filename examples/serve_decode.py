"""Batched serving demo: prefill + KV/state-cached decode for any assigned
architecture (reduced config on CPU).

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-27b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import registry

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2-2.7b", choices=registry.ARCHS)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen-len", type=int, default=32)
args = ap.parse_args()

cfg = registry.get_config(args.arch, smoke=True)
model = registry.get_model(cfg)
params = model.init(jax.random.PRNGKey(0), cfg)

key = jax.random.PRNGKey(1)
tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
max_seq = args.prompt_len + args.gen_len

print(f"{args.arch} (reduced): prefill {args.prompt_len} tokens, "
      f"decode {args.gen_len}")
t0 = time.time()
if cfg.family == "audio":
    frames = jax.random.normal(key, (args.batch, cfg.encoder_frames,
                                     cfg.d_model))
    logits, cache = model.prefill(params, {"frames": frames, "tokens": tokens},
                                  cfg)
elif cfg.family == "vlm":
    patches = jax.random.normal(key, (args.batch, cfg.n_patches, cfg.d_model))
    logits, cache = model.prefill(params, {"tokens": tokens,
                                           "patches": patches}, cfg)
elif cfg.family == "hybrid":
    logits, cache = model.prefill(params, tokens, cfg, max_seq=max_seq)
else:
    logits, cache = model.prefill(params, tokens, cfg)

# grow position-indexed caches to the full horizon
npatch = cfg.n_patches if cfg.family == "vlm" else 0
if "k" in cache and cfg.family not in ("hybrid", "ssm"):
    pad = max_seq + npatch - cache["k"].shape[-3]
    if pad > 0:
        w = [(0, 0)] * cache["k"].ndim
        w[-3] = (0, pad)
        cache["k"] = jnp.pad(cache["k"], w)
        cache["v"] = jnp.pad(cache["v"], w)

decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos, cfg))
tok = jnp.argmax(logits, axis=-1)
out = [tok]
for i in range(args.gen_len - 1):
    pos = jnp.full((args.batch,), args.prompt_len + i + npatch, jnp.int32)
    logits, cache = decode(params, tok, cache, pos)
    tok = jnp.argmax(logits, axis=-1)
    out.append(tok)
gen = jnp.stack(out, axis=1)
dt = time.time() - t0
print(f"generated {gen.shape} tokens in {dt:.2f}s "
      f"({args.batch * args.gen_len / dt:.1f} tok/s, greedy)")
print("sample token ids:", gen[0, :16].tolist())
