"""Quickstart: Q-GADMM on decentralized linear regression (paper Sec. V-A).

50 workers on a chain, each holding a private shard; 2-bit stochastic
quantization of model differences.  Runs in seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core import gadmm
from repro.core.quantizer import QuantizerConfig
from repro.data.synthetic import regression_shards

N_WORKERS, D = 50, 6

# 1) private data shards (California-housing-like synthetic)
xs, ys, _ = regression_shards(n_workers=N_WORKERS, samples=20000, d=D,
                              heterogeneous=False)
xs, ys = jnp.asarray(xs), jnp.asarray(ys)

# 2) the centralized optimum, for reference only (no worker ever sees this)
xtx = jnp.einsum("nmd,nme->nde", xs, xs)
xty = jnp.einsum("nmd,nm->nd", xs, ys)
theta_star = jnp.linalg.solve(xtx.sum(0), xty.sum(0))

# 3) Q-GADMM: chain ADMM + 2-bit stochastic quantization of model deltas
cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                        qcfg=QuantizerConfig(bits=2))
quad = gadmm.make_quadratic(xs, ys, cfg.rho)
state = gadmm.init_state(N_WORKERS, D, cfg)
step = jax.jit(functools.partial(gadmm.gadmm_step, q=quad, cfg=cfg))

print(f"{'iter':>5s} {'theta err':>12s} {'consensus':>12s} {'payload':>12s}")
for k in range(1, 201):
    state = step(state)
    if k % 25 == 0 or k == 1:
        err = float(jnp.max(jnp.abs(state.theta - theta_star[None])))
        cons, _ = gadmm.residuals(state)
        bits = gadmm.bits_per_round(cfg, N_WORKERS, D)
        print(f"{k:5d} {err:12.6f} {float(cons):12.6f} {bits:9d} bits"
              f" (vs {N_WORKERS * 32 * D} unquantized)")

print("\nEvery worker agrees with the centralized solution, having exchanged"
      "\nonly 2-bit quantized model differences with two neighbors.")
