"""End-to-end driver: decentralized Q-GADMM training of a ~100M-param LM on an
emulated multi-chip mesh (the paper's algorithm as the cross-group training
protocol; each worker's model is FSDP+TP sharded inside its device group).

  PYTHONPATH=src python examples/multipod_lm.py --steps 200

On CPU this emulates 8 devices as (4 data x 2 model); on TPU drop --devices to
use the production mesh (repro.launch.mesh.make_production_mesh).
"""
import argparse
import os

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--per-worker-batch", type=int, default=2)
ap.add_argument("--bits", type=int, default=8)
ap.add_argument("--d-model", type=int, default=640)
ap.add_argument("--layers", type=int, default=10)
ap.add_argument("--ckpt-dir", default=None)
args = ap.parse_args()

os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.gadmm import GADMMConfig  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402
from repro.data.pipeline import LMShardLoader  # noqa: E402
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state  # noqa: E402
from repro.launch.mesh import factor_mesh  # noqa: E402
from repro.models import dense  # noqa: E402
from repro.models.config import ArchConfig, num_params  # noqa: E402
from repro.train import checkpoint  # noqa: E402

# ~100M parameter dense LM
cfg = ArchConfig(
    name="lm-100m", family="dense", n_layers=args.layers,
    d_model=args.d_model, n_heads=8, n_kv_heads=8, d_ff=4 * args.d_model,
    vocab=50257, activation="silu", rope_theta=1e4)
print(f"model: {num_params(cfg)/1e6:.1f}M params")

devices = np.array(jax.devices())
d = args.workers
m = args.devices // d
mesh = Mesh(devices[: d * m].reshape(d, m), ("data", "model"))
wmesh = factor_mesh(mesh, args.workers)
print(f"mesh: {dict(wmesh.shape)}")

dcfg = DistConfig(
    num_workers=args.workers,
    gadmm=GADMMConfig(rho=0.5, quantize=True,
                      qcfg=QuantizerConfig(bits=args.bits), alpha=0.01),
    local_iters=1, local_lr=3e-4)
trainer = QGADMMTrainer(dense, cfg, dcfg, wmesh)

loader = LMShardLoader(args.workers, args.per_worker_batch, args.seq,
                       cfg.vocab)
state = init_state(lambda k: dense.init(k, cfg), jax.random.PRNGKey(0), dcfg)
batch = loader.next_batch()
state, batch = trainer.place(state, batch)
step_fn = trainer.jit_train_step(state, batch)

bspec = trainer.batch_specs(batch)
t0 = time.time()
for step in range(1, args.steps + 1):
    batch = jax.device_put(
        loader.next_batch(),
        jax.tree.map(lambda s: NamedSharding(wmesh, s), bspec,
                     is_leaf=lambda x: isinstance(x, P)))
    state, metrics = step_fn(state, batch)
    if step % 10 == 0 or step == 1:
        print(f"step {step:4d}: loss={float(metrics['loss']):.4f} "
              f"consensus={float(metrics['consensus_resid']):.3f} "
              f"R={float(metrics['radius_mean']):.5f} "
              f"({(time.time()-t0)/step:.2f}s/step)")
    if args.ckpt_dir and step % 100 == 0:
        checkpoint.save(args.ckpt_dir, step, state)
print("done")
