"""Q-SGADMM: decentralized DNN training (paper Sec. V-B).

10 workers, 3-layer MLP, 8-bit stochastic quantization, local Adam solver,
damped duals (alpha = 0.01).

  PYTHONPATH=src python examples/decentralized_dnn.py [--iters 30]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import QuantizerConfig
from repro.core.sgadmm import SGADMMConfig, SGADMMTrainer
from repro.data.synthetic import classification_shards
from repro.models import mlp

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=30)
ap.add_argument("--workers", type=int, default=10)
ap.add_argument("--bits", type=int, default=8)
args = ap.parse_args()

DIM = 64
xs, ys = classification_shards(n_workers=args.workers,
                               samples=600 * args.workers, dim=DIM)
xs, ys = jnp.asarray(xs), jnp.asarray(ys)
x_test, y_test = xs.reshape(-1, DIM), ys.reshape(-1)

p0 = mlp.init_params(jax.random.PRNGKey(0), layers=[(DIM, 48), (48, 10)])
cfg = SGADMMConfig(
    gadmm=GADMMConfig(rho=1.0, quantize=True,
                      qcfg=QuantizerConfig(bits=args.bits), alpha=0.01),
    local_iters=10, local_lr=3e-3, batch_size=100)
trainer = SGADMMTrainer(mlp.loss_fn, p0, args.workers, cfg)
print(f"model: {trainer.d} params; payload/round: "
      f"{trainer.bits_per_round()} bits "
      f"({args.workers * 32 * trainer.d} unquantized)")

rng = np.random.default_rng(0)
for it in range(1, args.iters + 1):
    sel = rng.integers(0, xs.shape[1], size=(args.workers, 100))
    xb = jnp.take_along_axis(xs, jnp.asarray(sel)[:, :, None], axis=1)
    yb = jnp.take_along_axis(ys, jnp.asarray(sel), axis=1)
    trainer.train_step(xb, yb)
    if it % 5 == 0 or it == 1:
        acc = float(mlp.accuracy(trainer.mean_params(), x_test, y_test))
        acc0 = float(mlp.accuracy(trainer.worker_params(0), x_test, y_test))
        print(f"round {it:3d}: acc(consensus)={acc:.3f} acc(worker0)={acc0:.3f}")
