"""Pallas TPU kernel: int4 nibble pack / unpack.

For b <= 4 quantizer bits the wire payload halves again by packing two levels
per byte before the collective-permute.  Elementwise VPU work; blocks are
(BLOCK_M, 2, 128) uint8 in VMEM.  Wire format (strided pairing, padded) is
defined in ref.py; kernel and oracle produce bit-identical buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LANES, _pad_rows, take_levels

Array = jax.Array

BLOCK_M = 256


def _pack_kernel(q_ref, out_ref):
    q = q_ref[...]  # (bm, 2, 128) uint8
    out_ref[...] = (q[:, 0, :] | (q[:, 1, :] << 4)).astype(jnp.uint8)


def _unpack_kernel(p_ref, out_ref):
    p = p_ref[...]  # (bm, 128) uint8
    lo = (p & 0xF).astype(jnp.uint8)
    hi = (p >> 4).astype(jnp.uint8)
    out_ref[...] = jnp.stack([lo, hi], axis=1)  # (bm, 2, 128)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack4(q: Array, *, interpret: bool = True) -> Array:
    """Pack flat uint8 levels (<16) into the wire format (128*ceil(n/256) bytes)."""
    flat = q.reshape(-1)
    rows = _pad_rows(flat.size)
    pad = rows * 2 * LANES - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint8)])
    q3 = flat.reshape(rows, 2, LANES)
    block_m = min(BLOCK_M, rows)
    grid = (-(-rows // block_m),)
    out = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, 2, LANES), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_m, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(q3)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def unpack4(packed: Array, n: int, *, interpret: bool = True) -> Array:
    """Unpack the wire format back to the first n uint8 levels."""
    rows = _pad_rows(n)
    p2 = packed.reshape(rows, LANES)
    block_m = min(BLOCK_M, rows)
    grid = (-(-rows // block_m),)
    out = pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, 2, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2, LANES), jnp.uint8),
        interpret=interpret,
    )(p2)
    # take_levels, not out.reshape(-1)[:n]: XLA:CPU miscompiles the fused
    # stack -> reshape -> odd-slice pattern for some n (see ref.take_levels).
    return take_levels(out[:, 0, :], out[:, 1, :], n)
