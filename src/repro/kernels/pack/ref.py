"""Pure-jnp oracle for int4 nibble pack/unpack (wire format for b <= 4).

Wire format: the flat uint8 level stream is zero-padded to a whole number of
(2*128)-element rows and viewed as (rows, 2, 128); byte r*128+c packs
lo = elem[r*256 + c] and hi = elem[r*256 + 128 + c].  The strided pairing keeps
the TPU lane dimension 128-aligned in the kernel; the packed buffer (including
padding) is what goes over the wire, size 128*ceil(n/256) bytes ~= n/2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LANES = 128


def _pad_rows(n: int) -> int:
    return -(-n // (2 * LANES))


def packed_len(n: int) -> int:
    """Bytes on the wire for n packed levels: 128 * ceil(n / 256).

    Single source of truth for the pack4 wire length — the sender
    (pack4 / pack4_ref) and every receiver (unpack4, the dist trainer's
    wire slicing, traffic accounting) must agree on it.
    """
    return LANES * _pad_rows(n)


def pack4_ref(q: Array) -> Array:
    """Pack flat uint8 values (< 16) into the strided nibble wire format."""
    flat = q.reshape(-1)
    rows = _pad_rows(flat.size)
    pad = rows * 2 * LANES - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint8)])
    q3 = flat.reshape(rows, 2, LANES)
    return (q3[:, 0, :] | (q3[:, 1, :] << 4)).astype(jnp.uint8).reshape(-1)


def take_levels(lo: Array, hi: Array, n: int) -> Array:
    """First n levels, in wire order, from (rows, 128) lo/hi nibble planes.

    Equivalent to jnp.stack([lo, hi], axis=1).reshape(-1)[:n], but slices the
    planes BEFORE interleaving: XLA:CPU miscompiles the fused
    stack -> reshape -> odd-length-slice pattern for some n (observed at
    n = 129: the lone element taken from the hi plane comes back as garbage
    under jit).  Shared by unpack4_ref and the Pallas unpack4 wrapper so both
    sides of the wire use the safe formulation.
    """
    full = n // (2 * LANES)
    tail = n - full * 2 * LANES
    parts = []
    if full:
        parts.append(jnp.stack([lo[:full], hi[:full]], axis=1).reshape(-1))
    if tail:
        parts.append(lo[full, :min(tail, LANES)])
        if tail > LANES:
            parts.append(hi[full, :tail - LANES])
    if not parts:
        return jnp.zeros((0,), jnp.uint8)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unpack4_ref(packed: Array, n: int) -> Array:
    """Inverse of pack4_ref, returning the first n levels."""
    rows = _pad_rows(n)
    p2 = packed.reshape(rows, LANES)
    lo = (p2 & 0xF).astype(jnp.uint8)
    hi = (p2 >> 4).astype(jnp.uint8)
    return take_levels(lo, hi, n)


# --------------------------------------------------- mixed bit-width wire ---
# Layerwise (per-leaf bit width) wire format: the flat level stream is a
# concatenation of per-leaf segments with STATIC (size, bits) framing — the
# same framing both endpoints derive from the shared LayerwiseConfig, so no
# extra sideband is needed.  Segments at <= 4 bits ride the pack4 nibble
# format (packed_len bytes, 256-level granularity paid per leaf); wider
# segments stay one byte per element.  mixed_packed_len is the accounting
# twin the trainer's layerwise wire_bits_per_round bills per transmitted
# leaf.


def _seg_packed(bits: int) -> bool:
    assert 1 <= int(bits) <= 8, bits
    return int(bits) <= 4


def mixed_packed_len(sizes, bits) -> int:
    """Bytes on the wire for per-segment (size, bits) framing."""
    assert len(sizes) == len(bits), (sizes, bits)
    return sum(packed_len(int(n)) if _seg_packed(b) else int(n)
               for n, b in zip(sizes, bits))


def pack_mixed_ref(q: Array, sizes, bits) -> Array:
    """Pack a flat uint8 level stream with per-segment bit widths.

    q: (sum(sizes),) uint8 levels, each segment's values < 2^bits[i].
    sizes/bits: static per-segment framing.  Returns a
    (mixed_packed_len(sizes, bits),) uint8 wire buffer.
    """
    flat = q.reshape(-1)
    assert flat.size == sum(int(n) for n in sizes), (flat.size, sizes)
    out, off = [], 0
    for n, b in zip(sizes, bits):
        n = int(n)
        seg = jax.lax.slice(flat, (off,), (off + n,))
        out.append(pack4_ref(seg) if _seg_packed(b) else seg)
        off += n
    if not out:
        return jnp.zeros((0,), jnp.uint8)
    return out[0] if len(out) == 1 else jnp.concatenate(out)


def unpack_mixed_ref(packed: Array, sizes, bits) -> Array:
    """Inverse of pack_mixed_ref: wire buffer -> flat (sum(sizes),) levels."""
    flat = packed.reshape(-1)
    assert flat.size == mixed_packed_len(sizes, bits), (flat.size, sizes)
    out, off = [], 0
    for n, b in zip(sizes, bits):
        n = int(n)
        if _seg_packed(b):
            m = packed_len(n)
            out.append(unpack4_ref(jax.lax.slice(flat, (off,), (off + m,)), n))
            off += m
        else:
            out.append(jax.lax.slice(flat, (off,), (off + n,)))
            off += n
    if not out:
        return jnp.zeros((0,), jnp.uint8)
    return out[0] if len(out) == 1 else jnp.concatenate(out)
