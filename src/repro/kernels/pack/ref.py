"""Pure-jnp oracle for int4 nibble pack/unpack (wire format for b <= 4).

Wire format: the flat uint8 level stream is zero-padded to a whole number of
(2*128)-element rows and viewed as (rows, 2, 128); byte r*128+c packs
lo = elem[r*256 + c] and hi = elem[r*256 + 128 + c].  The strided pairing keeps
the TPU lane dimension 128-aligned in the kernel; the packed buffer (including
padding) is what goes over the wire, size 128*ceil(n/256) bytes ~= n/2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LANES = 128


def _pad_rows(n: int) -> int:
    return -(-n // (2 * LANES))


def pack4_ref(q: Array) -> Array:
    """Pack flat uint8 values (< 16) into the strided nibble wire format."""
    flat = q.reshape(-1)
    rows = _pad_rows(flat.size)
    pad = rows * 2 * LANES - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint8)])
    q3 = flat.reshape(rows, 2, LANES)
    return (q3[:, 0, :] | (q3[:, 1, :] << 4)).astype(jnp.uint8).reshape(-1)


def unpack4_ref(packed: Array, n: int) -> Array:
    """Inverse of pack4_ref, returning the first n levels."""
    rows = _pad_rows(n)
    p2 = packed.reshape(rows, LANES)
    lo = (p2 & 0xF).astype(jnp.uint8)
    hi = (p2 >> 4).astype(jnp.uint8)
    out = jnp.stack([lo, hi], axis=1)  # (rows, 2, 128)
    return out.reshape(-1)[:n]
