"""Pure-jnp oracle for int4 nibble pack/unpack (wire format for b <= 4).

Wire format: the flat uint8 level stream is zero-padded to a whole number of
(2*128)-element rows and viewed as (rows, 2, 128); byte r*128+c packs
lo = elem[r*256 + c] and hi = elem[r*256 + 128 + c].  The strided pairing keeps
the TPU lane dimension 128-aligned in the kernel; the packed buffer (including
padding) is what goes over the wire, size 128*ceil(n/256) bytes ~= n/2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LANES = 128


def _pad_rows(n: int) -> int:
    return -(-n // (2 * LANES))


def packed_len(n: int) -> int:
    """Bytes on the wire for n packed levels: 128 * ceil(n / 256).

    Single source of truth for the pack4 wire length — the sender
    (pack4 / pack4_ref) and every receiver (unpack4, the dist trainer's
    wire slicing, traffic accounting) must agree on it.
    """
    return LANES * _pad_rows(n)


def pack4_ref(q: Array) -> Array:
    """Pack flat uint8 values (< 16) into the strided nibble wire format."""
    flat = q.reshape(-1)
    rows = _pad_rows(flat.size)
    pad = rows * 2 * LANES - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint8)])
    q3 = flat.reshape(rows, 2, LANES)
    return (q3[:, 0, :] | (q3[:, 1, :] << 4)).astype(jnp.uint8).reshape(-1)


def take_levels(lo: Array, hi: Array, n: int) -> Array:
    """First n levels, in wire order, from (rows, 128) lo/hi nibble planes.

    Equivalent to jnp.stack([lo, hi], axis=1).reshape(-1)[:n], but slices the
    planes BEFORE interleaving: XLA:CPU miscompiles the fused
    stack -> reshape -> odd-length-slice pattern for some n (observed at
    n = 129: the lone element taken from the hi plane comes back as garbage
    under jit).  Shared by unpack4_ref and the Pallas unpack4 wrapper so both
    sides of the wire use the safe formulation.
    """
    full = n // (2 * LANES)
    tail = n - full * 2 * LANES
    parts = []
    if full:
        parts.append(jnp.stack([lo[:full], hi[:full]], axis=1).reshape(-1))
    if tail:
        parts.append(lo[full, :min(tail, LANES)])
        if tail > LANES:
            parts.append(hi[full, :tail - LANES])
    if not parts:
        return jnp.zeros((0,), jnp.uint8)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unpack4_ref(packed: Array, n: int) -> Array:
    """Inverse of pack4_ref, returning the first n levels."""
    rows = _pad_rows(n)
    p2 = packed.reshape(rows, LANES)
    lo = (p2 & 0xF).astype(jnp.uint8)
    hi = (p2 >> 4).astype(jnp.uint8)
    return take_levels(lo, hi, n)
