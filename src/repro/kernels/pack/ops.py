"""Jit'd wrappers for nibble pack/unpack with impl dispatch."""
from __future__ import annotations

import jax

from . import pack as _kernel
from . import ref as _ref
from .ref import packed_len

Array = jax.Array

__all__ = ["pack4", "unpack4", "packed_len"]


def pack4(q: Array, *, impl: str = "pallas") -> Array:
    if impl == "ref":
        return _ref.pack4_ref(q)
    return _kernel.pack4(q.reshape(-1), interpret=impl != "pallas_compiled")


def unpack4(packed: Array, n: int, *, impl: str = "pallas") -> Array:
    if impl == "ref":
        return _ref.unpack4_ref(packed.reshape(-1), n)
    return _kernel.unpack4(packed.reshape(-1), n, interpret=impl != "pallas_compiled")
