"""Jit'd wrappers for nibble / mixed-width pack with impl dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pack as _kernel
from . import ref as _ref
from .ref import mixed_packed_len, packed_len

Array = jax.Array

__all__ = ["pack4", "unpack4", "packed_len",
           "pack_mixed", "unpack_mixed", "mixed_packed_len"]


def pack4(q: Array, *, impl: str = "pallas") -> Array:
    if impl == "ref":
        return _ref.pack4_ref(q)
    return _kernel.pack4(q.reshape(-1), interpret=impl != "pallas_compiled")


def unpack4(packed: Array, n: int, *, impl: str = "pallas") -> Array:
    if impl == "ref":
        return _ref.unpack4_ref(packed.reshape(-1), n)
    return _kernel.unpack4(packed.reshape(-1), n, interpret=impl != "pallas_compiled")


def pack_mixed(q: Array, sizes, bits, *, impl: str = "pallas") -> Array:
    """Per-segment (size, bits) mixed-width packing of a flat level stream.

    Segments at <= 4 bits go through the pack4 wire format (the selected
    impl's kernel), wider segments stay byte-per-element; the framing is
    static, shared by both endpoints (ref.pack_mixed_ref documents the
    format and is the bitwise oracle)."""
    if impl == "ref":
        return _ref.pack_mixed_ref(q, sizes, bits)
    flat = q.reshape(-1)
    out, off = [], 0
    for n, b in zip(sizes, bits):
        n = int(n)
        if n == 0:  # zero-size leaf: contributes no wire bytes
            continue
        seg = jax.lax.slice(flat, (off,), (off + n,))
        out.append(pack4(seg, impl=impl) if _ref._seg_packed(b) else seg)
        off += n
    if not out:
        return jnp.zeros((0,), jnp.uint8)
    return out[0] if len(out) == 1 else jnp.concatenate(out)


def unpack_mixed(packed: Array, sizes, bits, *, impl: str = "pallas") -> Array:
    """Inverse of pack_mixed (same static framing)."""
    if impl == "ref":
        return _ref.unpack_mixed_ref(packed, sizes, bits)
    flat = packed.reshape(-1)
    out, off = [], 0
    for n, b in zip(sizes, bits):
        n = int(n)
        if n == 0:
            continue
        if _ref._seg_packed(b):
            m = packed_len(n)
            out.append(unpack4(jax.lax.slice(flat, (off,), (off + m,)), n,
                               impl=impl))
            off += m
        else:
            out.append(jax.lax.slice(flat, (off,), (off + n,)))
            off += n
    if not out:
        return jnp.zeros((0,), jnp.uint8)
    return out[0] if len(out) == 1 else jnp.concatenate(out)
