"""Pure-jnp oracle for the intra-chunk SSD kernel (Mamba2 / SSD duality).

For one chunk of length Q (single head group, G=1):

  scores[t, k] = (C_t . B_k) * exp(la[t,h] - la[k,h]) * dt[k,h]   for k <= t
  y_intra[t, h] = sum_k scores[t, k, h] * x[k, h, :]

This is the quadratic (attention-like) half of the chunked SSD algorithm;
the inter-chunk recurrence stays a lax.scan (it is tiny).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_intra_ref(x: Array, dt: Array, la: Array, b: Array, c: Array) -> Array:
    """x: (Q, H, P); dt, la: (Q, H); b, c: (Q, N).  Returns (Q, H, P) f32."""
    f32 = jnp.float32
    x, dt, la, b, c = (t.astype(f32) for t in (x, dt, la, b, c))
    q = x.shape[0]
    cb = jnp.einsum("tn,kn->tk", c, b)                      # (Q, Q)
    seg = la[:, None, :] - la[None, :, :]                   # (Q, K, H)
    tri = jnp.tril(jnp.ones((q, q), bool))[:, :, None]
    decay = jnp.exp(jnp.where(tri, seg, -jnp.inf))          # (Q, K, H)
    w = cb[:, :, None] * decay * dt[None, :, :]             # (Q, K, H)
    return jnp.einsum("tkh,khp->thp", w, x)
