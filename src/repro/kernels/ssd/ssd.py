"""Pallas TPU kernel: intra-chunk SSD (the quadratic half of Mamba2's chunked
state-space-duality algorithm).

TPU mapping: grid over (batch*chunks, head-blocks).  Per grid cell everything
lives in VMEM:
  C, B: (Q, N)           -> one (Q, Q) MXU matmul
  la, dt: (Q, HB)        -> elementwise decay weights (VPU)
  x: (Q, HB, P)          -> HB small (Q, Q) x (Q, P) MXU matmuls
with Q = chunk length (128/256), N = state (64-128), P = head dim (64):
Q, N, P are all MXU-friendly multiples; the decay matrix never touches HBM —
that is the kernel's point (the jnp path materializes (B, NC, Q, Q, H) decay
tensors through HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG_INF = float("-inf")


def _kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, out_ref):
    # blocks (leading grid dim of size 1 squeezed on read):
    #   x (Q, HB, P); dt, la (Q, HB); b, c (Q, N)
    f32 = jnp.float32
    x = x_ref[0].astype(f32)                         # (Q, HB, P)
    dt = dt_ref[0].astype(f32)                       # (Q, HB)
    la = la_ref[0].astype(f32)                       # (Q, HB)
    bmat = b_ref[0].astype(f32)                      # (Q, N)
    cmat = c_ref[0].astype(f32)                      # (Q, N)
    q, hb = x.shape[0], x.shape[1]
    cb = jnp.dot(cmat, bmat.T,
                 preferred_element_type=f32)         # (Q, Q) on the MXU
    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))

    def head(h, acc):
        seg = la[:, None, h] - la[None, :, h]        # (Q, Q)
        decay = jnp.exp(jnp.where(tri, seg, NEG_INF))
        w = cb * decay * dt[None, :, h]              # (Q, Q)
        yh = jnp.dot(w, x[:, h, :],
                     preferred_element_type=f32)     # (Q, P) MXU
        return acc.at[:, h, :].set(yh)

    out = jax.lax.fori_loop(0, hb, head, jnp.zeros(x.shape, f32))
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_intra(x: Array, dt: Array, la: Array, b: Array, c: Array,
              *, head_block: int = 8, interpret: bool = True) -> Array:
    """Batched intra-chunk SSD.

    x: (BC, Q, H, P); dt, la: (BC, Q, H); b, c: (BC, Q, N) — BC = batch*chunks
    flattened, G=1 groups.  Returns (BC, Q, H, P) f32.
    """
    bc, q, h, p = x.shape
    n = b.shape[-1]
    hb = min(head_block, h)
    nhb = -(-h // hb)
    pad = nhb * hb - h
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        la = jnp.pad(la, ((0, 0), (0, 0), (0, pad)))
    grid = (bc, nhb)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, hb, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, hb), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, hb), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, hb, p), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, q, nhb * hb, p), jnp.float32),
        interpret=interpret,
    )(x, dt, la, b, c)
    return out[:, :, :h]
