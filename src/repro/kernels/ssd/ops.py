"""Jit'd wrapper for the intra-chunk SSD kernel with impl dispatch."""
from __future__ import annotations

import jax

from . import ref as _ref
from . import ssd as _kernel

Array = jax.Array


def ssd_intra(x: Array, dt: Array, la: Array, b: Array, c: Array,
              *, impl: str = "pallas") -> Array:
    """x: (BC, Q, H, P); dt/la: (BC, Q, H); b/c: (BC, Q, N) -> (BC, Q, H, P)."""
    if impl == "ref":
        return jax.vmap(_ref.ssd_intra_ref)(x, dt, la, b, c)
    return _kernel.ssd_intra(x, dt, la, b, c,
                             interpret=impl != "pallas_compiled")
