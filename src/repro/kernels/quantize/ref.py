"""Pure-jnp oracle for the fused stochastic quantize-dequantize kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_dequantize_ref(
    theta: Array,
    theta_hat_prev: Array,
    u: Array,
    radius: Array,
    levels: Array,
) -> tuple[Array, Array]:
    """Reference for the fused kernel.

    Args:
      theta, theta_hat_prev: same-shape float tensors.
      u: uniform [0,1) random values, same shape (rounding randomness).
      radius: f32, R = ||theta - theta_hat_prev||_inf (precomputed; in the
        distributed setting it is an all-reduce-max over the worker group).
        A scalar, or any shape broadcastable against theta (per-element R,
        used by the dist trainer's per_tensor radius mode).
      levels: f32, 2^b - 1.  A scalar, or any shape broadcastable against
        theta (per-element levels — the dist trainer's layerwise per-leaf
        bit widths, expanded position-wise like the per_tensor radius).

    Returns:
      q:        uint8 levels in [0, levels]
      theta_hat: reconstructed (sender==receiver) new hat, dtype of theta_hat_prev.
    """
    x = theta.astype(jnp.float32)
    h = theta_hat_prev.astype(jnp.float32)
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    c = (x - h + radius) / step
    low = jnp.floor(c)
    p = c - low
    q = low + (u < p).astype(jnp.float32)
    q = jnp.clip(q, 0.0, levels)
    hat = h + step * q - radius
    hat = jnp.where(radius > 0, hat, h)
    q = jnp.where(radius > 0, q, jnp.zeros_like(q))
    return q.astype(jnp.uint8), hat.astype(theta_hat_prev.dtype)
