"""Pallas TPU kernel: fused stochastic quantize + dequantize.

The Q-GADMM per-iteration communication hot path touches every parameter:
read theta and theta_hat_prev, compute level indices with stochastic rounding,
write the uint8 payload AND the reconstructed theta_hat (sender keeps it so its
state matches the receiver bit-for-bit).  Unfused, XLA materializes the f32
intermediates (c, floor, p, compare) in HBM; fused, the op is 3 reads
(theta, hat, u) + 2 writes (q, hat_new) of which q is 1 byte/elem.

TPU mapping: pure VPU elementwise work tiled in (BLOCK_M, 128) VMEM blocks,
lane-dim 128-aligned.  Scalars (radius, levels) ride in SMEM via (1,1) blocks.
Arithmetic intensity is O(1) FLOP/byte => the win is HBM traffic, not MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_M = 256  # sublane-dim block; lane dim fixed at 128
LANES = 128


def _kernel(r_ref, lv_ref, theta_ref, hat_ref, u_ref, q_ref, newhat_ref):
    radius = r_ref[0, 0]
    levels = lv_ref[0, 0]
    x = theta_ref[...].astype(jnp.float32)
    h = hat_ref[...].astype(jnp.float32)
    u = u_ref[...]
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    c = (x - h + radius) / step
    low = jnp.floor(c)
    p = c - low
    q = low + (u < p).astype(jnp.float32)
    q = jnp.clip(q, 0.0, levels)
    hat = h + step * q - radius
    active = radius > 0
    q_ref[...] = jnp.where(active, q, jnp.zeros_like(q)).astype(jnp.uint8)
    newhat_ref[...] = jnp.where(active, hat, h).astype(newhat_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_dequantize(
    theta: Array,
    theta_hat_prev: Array,
    u: Array,
    radius: Array,
    levels: Array,
    *,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused stochastic quantize-dequantize over an arbitrary-shape tensor.

    See ref.quantize_dequantize_ref for semantics.  interpret=True executes the
    kernel body in Python on CPU (this container); on TPU pass interpret=False.
    """
    orig_shape = theta.shape
    n = theta.size
    cols = LANES
    rows = -(-n // cols)
    pad = rows * cols - n

    def to2d(x, fill):
        flat = x.reshape(-1)
        if pad:
            flat = jnp.concatenate([flat, jnp.full((pad,), fill, flat.dtype)])
        return flat.reshape(rows, cols)

    theta2 = to2d(theta, 0)
    hat2 = to2d(theta_hat_prev, 0)
    u2 = to2d(u.astype(jnp.float32), 1.0)  # u=1 never rounds up on padding

    block_m = min(BLOCK_M, rows)
    grid = (-(-rows // block_m),)
    r2 = radius.astype(jnp.float32).reshape(1, 1)
    lv2 = levels.astype(jnp.float32).reshape(1, 1)

    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    tile = pl.BlockSpec((block_m, cols), lambda i: (i, 0))
    q2, newhat2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.uint8),
            jax.ShapeDtypeStruct((rows, cols), theta_hat_prev.dtype),
        ],
        interpret=interpret,
    )(r2, lv2, theta2, hat2, u2)

    q = q2.reshape(-1)[:n].reshape(orig_shape)
    newhat = newhat2.reshape(-1)[:n].reshape(orig_shape)
    return q, newhat
