"""Pallas TPU kernel: fused stochastic quantize + dequantize.

The Q-GADMM per-iteration communication hot path touches every parameter:
read theta and theta_hat_prev, compute level indices with stochastic rounding,
write the uint8 payload AND the reconstructed theta_hat (sender keeps it so its
state matches the receiver bit-for-bit).  Unfused, XLA materializes the f32
intermediates (c, floor, p, compare) in HBM; fused, the op is 3 reads
(theta, hat, u) + 2 writes (q, hat_new) of which q is 1 byte/elem.

TPU mapping: pure VPU elementwise work tiled in (BLOCK_M, 128) VMEM blocks,
lane-dim 128-aligned.  Scalars (radius, levels) ride in SMEM via (1,1) blocks.
Arithmetic intensity is O(1) FLOP/byte => the win is HBM traffic, not MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_M = 256  # sublane-dim block; lane dim fixed at 128
LANES = 128


def _qdq_math(radius, levels, theta_ref, hat_ref, u_ref, q_ref, newhat_ref):
    """Shared kernel body: the scalar-radius and tile-radius variants must
    stay bit-identical (the trainer's cross-impl parity contract), so the
    arithmetic lives in exactly one place.  radius is a scalar or a tile
    broadcastable against the block."""
    x = theta_ref[...].astype(jnp.float32)
    h = hat_ref[...].astype(jnp.float32)
    u = u_ref[...]
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    c = (x - h + radius) / step
    low = jnp.floor(c)
    p = c - low
    q = low + (u < p).astype(jnp.float32)
    q = jnp.clip(q, 0.0, levels)
    hat = h + step * q - radius
    active = radius > 0
    q_ref[...] = jnp.where(active, q, jnp.zeros_like(q)).astype(jnp.uint8)
    newhat_ref[...] = jnp.where(active, hat, h).astype(newhat_ref.dtype)


def _kernel(r_ref, lv_ref, theta_ref, hat_ref, u_ref, q_ref, newhat_ref):
    _qdq_math(r_ref[0, 0], lv_ref[0, 0], theta_ref, hat_ref, u_ref, q_ref,
              newhat_ref)


def _kernel_vec_r(lv_ref, theta_ref, hat_ref, u_ref, r_ref, q_ref, newhat_ref):
    """Per-element radius variant: R rides in a VMEM tile instead of SMEM.

    Used by the dist trainer's per_tensor radius mode, where the per-tensor
    scalars are expanded (segment-scalar gather) into one radius value per
    wire-buffer position."""
    _qdq_math(r_ref[...], lv_ref[0, 0], theta_ref, hat_ref, u_ref, q_ref,
              newhat_ref)


def _kernel_vec_rl(theta_ref, hat_ref, u_ref, r_ref, lv_ref, q_ref,
                   newhat_ref):
    """Per-element radius AND levels variant: both ride in VMEM tiles.

    Used by the dist trainer's layerwise mode, where each leaf owns its own
    bit width — the per-leaf (2^b - 1) scalars are expanded into one levels
    value per wire-buffer position, same segment-scalar gather as the
    per_tensor radius.  Padding positions carry levels = 1 (never 0: the
    shared math divides by levels) with R = 0 keeping them inert."""
    _qdq_math(r_ref[...], lv_ref[...], theta_ref, hat_ref, u_ref, q_ref,
              newhat_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_dequantize(
    theta: Array,
    theta_hat_prev: Array,
    u: Array,
    radius: Array,
    levels: Array,
    *,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused stochastic quantize-dequantize over an arbitrary-shape tensor.

    See ref.quantize_dequantize_ref for semantics.  `radius` is a scalar
    (one R for the whole tensor, SMEM path) or an array of theta's shape
    (per-element R, VMEM tile path — the dist trainer's per_tensor mode).
    `levels` is a scalar (one bit width, SMEM) or an array of theta's shape
    (per-element levels, VMEM tile — the layerwise per-leaf bit widths); the
    per-element-levels path always runs the vec-R kernel (a scalar radius is
    broadcast).  interpret=True executes the kernel body in Python on CPU
    (this container); on TPU pass interpret=False.
    """
    orig_shape = theta.shape
    n = theta.size
    cols = LANES
    rows = -(-n // cols)
    pad = rows * cols - n

    def to2d(x, fill):
        flat = x.reshape(-1)
        if pad:
            flat = jnp.concatenate([flat, jnp.full((pad,), fill, flat.dtype)])
        return flat.reshape(rows, cols)

    theta2 = to2d(theta, 0)
    hat2 = to2d(theta_hat_prev, 0)
    u2 = to2d(u.astype(jnp.float32), 1.0)  # u=1 never rounds up on padding

    block_m = min(BLOCK_M, rows)
    grid = (-(-rows // block_m),)

    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    tile = pl.BlockSpec((block_m, cols), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((rows, cols), jnp.uint8),
        jax.ShapeDtypeStruct((rows, cols), theta_hat_prev.dtype),
    ]
    if levels.ndim > 0:
        # layerwise per-element levels: fill padding with 1 (the math
        # divides by levels), R = 0 keeps those positions inert
        lv2 = to2d(levels.astype(jnp.float32), 1.0)
        r_full = (jnp.broadcast_to(radius, theta.shape) if radius.ndim == 0
                  else radius)
        r2 = to2d(r_full.astype(jnp.float32), 0.0)
        q2, newhat2 = pl.pallas_call(
            _kernel_vec_rl,
            grid=grid,
            in_specs=[tile, tile, tile, tile, tile],
            out_specs=[tile, tile],
            out_shape=out_shape,
            interpret=interpret,
        )(theta2, hat2, u2, r2, lv2)
        q = _take_flat(q2, n).reshape(orig_shape)
        newhat = _take_flat(newhat2, n).reshape(orig_shape)
        return q, newhat
    lv2 = levels.astype(jnp.float32).reshape(1, 1)
    if radius.ndim == 0:
        r2 = radius.astype(jnp.float32).reshape(1, 1)
        q2, newhat2 = pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[scalar_spec, scalar_spec, tile, tile, tile],
            out_specs=[tile, tile],
            out_shape=out_shape,
            interpret=interpret,
        )(r2, lv2, theta2, hat2, u2)
    else:
        # R == 0 on padding: inactive lanes write q = 0, discarded below.
        r2 = to2d(radius.astype(jnp.float32), 0.0)
        q2, newhat2 = pl.pallas_call(
            _kernel_vec_r,
            grid=grid,
            in_specs=[scalar_spec, tile, tile, tile, tile],
            out_specs=[tile, tile],
            out_shape=out_shape,
            interpret=interpret,
        )(lv2, theta2, hat2, u2, r2)

    q = _take_flat(q2, n).reshape(orig_shape)
    newhat = _take_flat(newhat2, n).reshape(orig_shape)
    return q, newhat


def _take_flat(x2: Array, n: int) -> Array:
    """First n elements of a (rows, cols) buffer in row-major order.

    Equivalent to x2.reshape(-1)[:n], but slices the row/tail parts before
    flattening: XLA:CPU miscompiles the fused reshape -> odd-length-slice
    pattern for some n under SPMD partitioning (same bug family as
    kernels/pack ref.take_levels)."""
    rows, cols = x2.shape
    full = n // cols
    tail = n - full * cols
    parts = []
    if full:
        parts.append(x2[:full].reshape(-1))
    if tail:
        parts.append(x2[full, :tail])
    if not parts:
        return jnp.zeros((0,), x2.dtype)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)
