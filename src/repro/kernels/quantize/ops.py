"""Public jit'd wrapper for the fused stochastic quantize-dequantize kernel.

Dispatches to the Pallas kernel (interpret mode on CPU, compiled on TPU) or to
the pure-jnp reference, selected by `impl`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quantize as _kernel
from . import ref as _ref

Array = jax.Array


def quantize_dequantize(
    theta: Array,
    theta_hat_prev: Array,
    key: Array,
    radius: Array,
    bits: Array | int,
    *,
    impl: str = "pallas",
) -> tuple[Array, Array]:
    """Stochastically quantize (theta - theta_hat_prev); return (q uint8, new hat).

    impl: 'pallas' (interpret on CPU), 'pallas_compiled' (TPU), or 'ref'.
    radius: scalar, or theta-shaped for per-element quantization ranges.
    """
    u = jax.random.uniform(key, theta.shape, jnp.float32)
    levels = (2.0 ** jnp.asarray(bits, jnp.float32)) - 1.0
    radius = jnp.asarray(radius, jnp.float32)
    if impl == "ref":
        return _ref.quantize_dequantize_ref(theta, theta_hat_prev, u, radius, levels)
    interpret = impl != "pallas_compiled"
    return _kernel.quantize_dequantize(
        theta, theta_hat_prev, u, radius, levels, interpret=interpret
    )
