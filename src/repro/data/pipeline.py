"""Host-side data pipeline: per-worker token shards -> device batches.

Each GADMM worker owns a disjoint shard of the corpus (decentralized data
never leaves the worker — that is the paper's privacy premise).  The loader
yields batches shaped (W, per_worker_batch, seq) ready for
QGADMMTrainer.place().
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from .synthetic import token_shards


@dataclasses.dataclass
class LMShardLoader:
    n_workers: int
    per_worker_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    tokens_per_worker: int = 0

    def __post_init__(self):
        need = self.per_worker_batch * (self.seq_len + 1) * 64
        self.tokens_per_worker = max(self.tokens_per_worker, need)
        self.shards = token_shards(self.n_workers, self.tokens_per_worker,
                                   self.vocab, self.seed)
        self.rng = np.random.default_rng(self.seed + 1)

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        w, b, s = self.n_workers, self.per_worker_batch, self.seq_len
        starts = self.rng.integers(0, self.tokens_per_worker - s - 1,
                                   size=(w, b))
        idx = starts[..., None] + np.arange(s + 1)[None, None]
        window = np.take_along_axis(
            self.shards, idx.reshape(w, b * (s + 1)), axis=1
        ).reshape(w, b, s + 1)
        return {"tokens": window[..., :-1].astype(np.int32),
                "labels": window[..., 1:].astype(np.int32)}


@dataclasses.dataclass
class ExtraInputs:
    """Stubbed modality frontends (VLM patches / audio frames)."""

    @staticmethod
    def patches(n_workers, per_batch, n_patches, d_model, seed=0):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(n_workers, per_batch, n_patches, d_model)
                          ).astype(np.float32)

    @staticmethod
    def frames(n_workers, per_batch, n_frames, d_model, seed=0):
        rng = np.random.default_rng(seed)
        return rng.normal(size=(n_workers, per_batch, n_frames, d_model)
                          ).astype(np.float32)
