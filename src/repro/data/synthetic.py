"""Synthetic datasets standing in for the paper's offline-unavailable data.

* regression_shards: California-housing-like linear regression (20k samples,
  d=6 features), split uniformly across N workers.  Features are correlated
  and non-isotropic to mimic real tabular data; a ground-truth weight vector
  plus noise generates targets.
* classification_shards: MNIST-like 10-class task (784-dim inputs built from
  class prototypes + structured noise), split across N workers, for the
  Q-SGADMM DNN experiments.
* token_shards: synthetic LM token streams for the architecture training demos.
"""
from __future__ import annotations

import numpy as np


def regression_shards(n_workers: int = 50, samples: int = 20000, d: int = 6,
                      seed: int = 0, noise: float = 0.1, heterogeneous: bool = True):
    """Returns xs (N, m, d), ys (N, m) float32."""
    rng = np.random.default_rng(seed)
    # correlated feature covariance
    a = rng.normal(size=(d, d))
    cov = a @ a.T / d + 0.5 * np.eye(d)
    chol = np.linalg.cholesky(cov)
    x = rng.normal(size=(samples, d)) @ chol.T
    w_true = rng.normal(size=(d,))
    y = x @ w_true + noise * rng.normal(size=(samples,))
    m = samples // n_workers
    x, y = x[: m * n_workers], y[: m * n_workers]
    if heterogeneous:
        # sort by a feature so shards are non-iid (harder consensus), then
        # interleave lightly so each shard still spans the space
        order = np.argsort(x[:, 0] + 0.3 * rng.normal(size=len(x)))
        x, y = x[order], y[order]
    xs = x.reshape(n_workers, m, d).astype(np.float32)
    ys = y.reshape(n_workers, m).astype(np.float32)
    return xs, ys, w_true.astype(np.float32)


def classification_shards(n_workers: int = 10, samples: int = 6000,
                          dim: int = 784, classes: int = 10, seed: int = 0):
    """MNIST-like synthetic classification: xs (N, m, dim), ys (N, m) int32."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, dim)) * 1.5
    # low-rank structured noise (like stroke variation)
    basis = rng.normal(size=(classes, 16, dim))
    labels = rng.integers(0, classes, size=samples)
    coef = rng.normal(size=(samples, 16))
    x = protos[labels] + np.einsum("sk,skd->sd", coef, basis[labels]) * 0.7
    x += 0.8 * rng.normal(size=(samples, dim))
    x = np.tanh(x)  # bounded like pixel intensities
    m = samples // n_workers
    xs = x[: m * n_workers].reshape(n_workers, m, dim).astype(np.float32)
    ys = labels[: m * n_workers].reshape(n_workers, m).astype(np.int32)
    return xs, ys


def token_shards(n_workers: int, tokens_per_worker: int, vocab: int, seed: int = 0):
    """Zipf-distributed synthetic token stream per worker (for LM demos)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    out = rng.choice(vocab, size=(n_workers, tokens_per_worker), p=p)
    return out.astype(np.int32)
