"""Recompute roofline terms from saved HLO dumps (no recompilation).

  PYTHONPATH=src python -m repro.launch.reanalyze hlo_dir out.json
"""
import glob
import gzip
import json
import os
import re
import sys

from repro.launch import hlo_stats
from repro.launch.dryrun import SHAPES, _roofline
from repro.models import registry


def main(argv=None):
    argv = argv or sys.argv[1:]
    hlo_dir, out = argv[0], argv[1]
    results = []
    for path in sorted(glob.glob(os.path.join(hlo_dir, "*.hlo.gz"))):
        base = os.path.basename(path)[: -len(".hlo.gz")]
        m = re.match(r"(.+)_(train_4k|prefill_32k|decode_32k|long_500k)_([\dx]+)$",
                     base)
        if not m:
            continue
        arch, shape, meshtag = m.groups()
        chips = 1
        for v in meshtag.split("x"):
            chips *= int(v)
        cfg = registry.get_config(arch)
        with gzip.open(path, "rt") as f:
            text = f.read()
        coll = hlo_stats.collective_stats(text)
        cost = hlo_stats.hlo_cost(text)
        roof = _roofline(cost, coll.total_bytes, chips, cfg, shape)
        results.append(dict(arch=arch, shape=shape, mesh=meshtag, chips=chips,
                            collectives=coll.bytes_by_kind, **roof))
        print(f"{arch} x {shape} [{meshtag}]: dominant={roof['dominant']} "
              f"c={roof['compute_s']*1e3:.1f}ms m={roof['memory_s']*1e3:.1f}ms "
              f"x={roof['collective_s']*1e3:.1f}ms useful={roof['useful_flops_ratio']:.3f}")
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"wrote {out} ({len(results)} entries)")


if __name__ == "__main__":
    main()
