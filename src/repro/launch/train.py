"""End-to-end decentralized training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
      --steps 50 --workers 4 --devices 8

On CPU (this container) use --smoke + --devices N to emulate an N-chip mesh;
on real hardware drop --devices and the production mesh is used.
"""
import argparse
import os
import sys


def main(argv=None):
    from repro.core.topology import TOPOLOGY_KINDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU emulation)")
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rho", type=float, default=1.0)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--local-iters", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="gauss-seidel",
                    choices=["gauss-seidel", "jacobi"])
    ap.add_argument("--topology", default="chain",
                    choices=list(TOPOLOGY_KINDS),
                    help="worker graph (ring: even workers; torus2d: "
                         "workers %% 4 == 0)")
    ap.add_argument("--censor", action="store_true",
                    help="CQ-GGADMM censored transmissions")
    ap.add_argument("--censor-tau", type=float, default=0.05)
    ap.add_argument("--censor-xi", type=float, default=0.9)
    ap.add_argument("--staleness", type=int, default=0,
                    help="S>0 pipelines the exchange: compute runs against "
                         "S-round-old neighbor hats while S payload rounds "
                         "stay in flight (dist.qgadmm staleness pipeline)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-round Bernoulli participation rate in (0, 1]; "
                         "<1 drops workers from random rounds with "
                         "degree-renormalized neighbor sums "
                         "(DistConfig.participation)")
    ap.add_argument("--layerwise", action="store_true",
                    help="L-FGADMM per-leaf wire: large leaves transmit "
                         "every --layerwise-period rounds at per-leaf bit "
                         "widths (DistConfig.layerwise)")
    ap.add_argument("--layerwise-period", type=int, default=2,
                    help="exchange period of the large leaves (top "
                         "half of the model by parameter count)")
    ap.add_argument("--bit-budget", type=int, default=None, metavar="BITS",
                    help="adaptive per-leaf bit allocation under a fixed "
                         "sum(bits_l * d_l) payload budget per "
                         "transmission (implies --layerwise)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10,
                    help="drain/print telemetry every N steps (one batched "
                         "device_get per window; no per-step host sync)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write repro.obs/v1 JSONL run records here "
                         "(manifest first line, step records per window)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event file of host-side "
                         "compile/dispatch/drain spans (Perfetto-loadable)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.censor import CensorConfig
    from repro.core.gadmm import GADMMConfig
    from repro.core.quantizer import LayerwiseConfig, QuantizerConfig
    from repro.data.pipeline import ExtraInputs, LMShardLoader
    from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
    from repro.launch.mesh import factor_mesh, make_production_mesh
    from repro.models import registry
    from repro.train import checkpoint

    devices = np.array(jax.devices())
    if args.devices:
        model_par = max(1, args.devices // (args.workers * 1))
        # simple (data, model) grid for emulation
        d = args.workers
        m = args.devices // d
        mesh = Mesh(devices[: d * m].reshape(d, m), ("data", "model"))
    else:
        mesh = make_production_mesh()
    wmesh = factor_mesh(mesh, args.workers)
    print(f"mesh: {dict(wmesh.shape)}")

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    model = registry.get_model(cfg)
    dcfg = DistConfig(
        num_workers=args.workers,
        gadmm=GADMMConfig(rho=args.rho, quantize=not args.no_quantize,
                          qcfg=QuantizerConfig(bits=args.bits), alpha=0.01),
        local_iters=args.local_iters, local_lr=args.lr, mode=args.mode,
        topology=args.topology, staleness=args.staleness,
        participation=args.participation,
        censor=(CensorConfig(tau=args.censor_tau, xi=args.censor_xi)
                if args.censor else None),
        layerwise=(LayerwiseConfig(large_leaf_period=args.layerwise_period,
                                   budget_bits=args.bit_budget)
                   if args.layerwise or args.bit_budget is not None
                   else None))
    trainer = QGADMMTrainer(model, cfg, dcfg, wmesh)

    loader = LMShardLoader(args.workers, args.per_worker_batch, args.seq,
                           cfg.vocab)

    def add_extras(b):
        if cfg.family == "vlm":
            b["patches"] = ExtraInputs.patches(
                args.workers, args.per_worker_batch, cfg.n_patches, cfg.d_model)
        if cfg.family == "audio":
            b["frames"] = ExtraInputs.frames(
                args.workers, args.per_worker_batch, cfg.encoder_frames,
                cfg.d_model)
        return b

    from repro.obs import checks, record, trace

    tw = trace.TraceWriter() if args.trace else None

    def span(name, **kw):
        import contextlib
        return tw.span(name, **kw) if tw else contextlib.nullcontext()

    state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0),
                       dcfg)
    batch0 = add_extras(loader.next_batch())
    state, _ = trainer.place(state, batch0)
    with span("compile"):
        step_fn = trainer.jit_train_step(state, batch0)

    start = 0
    if args.ckpt_dir and (s := checkpoint.latest_step(args.ckpt_dir)) is not None:
        state = checkpoint.restore(args.ckpt_dir, s, state)
        state, _ = trainer.place(state, batch0)
        start = s
        print(f"restored step {s}")

    manifest = record.manifest_record(
        dcfg, seed=0, topology=args.topology, num_workers=args.workers,
        extra={"cli": "launch.train", "arch": args.arch,
               "steps": args.steps, "mesh": dict(wmesh.shape)})
    mlog = record.MetricsLog(path=args.metrics_out, manifest=manifest,
                             log_every=args.log_every)
    check = checks.enabled(dcfg)

    import time
    t0 = time.time()

    def show(rec):
        m = rec["metrics"]
        extra = (f" skip={m['skip_rate']:.2f} "
                 f"wire_bits={m['wire_bits_per_round']:.3g}"
                 if args.censor or dcfg.layerwise is not None else "")
        print(f"step {rec['step'] + 1}: loss={m['loss']:.4f} "
              f"resid={m['consensus_resid']:.4f} "
              f"R={m['radius_mean']:.5f}"
              f"{extra} "
              f"({rec['wall_s']:.2f}s/step)")

    for step in range(start, args.steps):
        batch = add_extras(loader.next_batch())
        batch = jax.device_put(batch, jax.tree.map(
            lambda s: jax.sharding.NamedSharding(wmesh, s),
            trainer.batch_specs(batch),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        with span("step", step=step):
            state, metrics = step_fn(state, batch)
        # buffer without touching the device arrays; one batched
        # device_get per --log-every window (the old per-step float()
        # forced a dispatch sync every printing step)
        mlog.append(step, metrics)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            with span("drain", step=step):
                recs = mlog.drain()
            if recs:
                show(recs[-1])
            if check and recs:
                checks.check_step_window(trainer, state, recs)
                checks.check_edge_mirrors(trainer, state)
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
    dt = time.time() - t0
    steps_run = max(args.steps - start, 1)
    mlog.close(summary={"steps": args.steps, "wall_s": dt,
                        "s_per_step": dt / steps_run,
                        "checked": bool(check)})
    if args.metrics_out:
        print(f"wrote {args.metrics_out}")
    if tw:
        tw.write(args.trace)
        print(f"wrote {args.trace}")
    if check:
        print("REPRO_CHECK: wire accounting + edge mirrors OK")
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
