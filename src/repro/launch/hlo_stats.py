"""Parse compiled HLO text: collective ops, their wire bytes, and loop trip
counts (collectives inside scan bodies count x trip_count).

Wire-bytes model (per device, per execution of the op):
  collective-permute: result bytes                      (send == recv)
  all-to-all:         result bytes
  all-gather:         result bytes * (g-1)/g  ~ result  (received)
  all-reduce:         2 * result bytes * (g-1)/g ~ 2x   (ring)
  reduce-scatter:     result bytes * (g-1)              (sends operand-share)
where g = replica group size when parseable.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*) = ((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*)) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPSZ_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPLIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _computation_blocks(text: str) -> dict:
    """Split HLO text into named computation bodies."""
    blocks = {}
    cur, buf = None, []
    for line in text.splitlines():
        m = re.match(r"^(?:ENTRY )?%?([\w.\-]+) (?:\([^)]*\))? ?->.*\{", line)
        if m is None:
            m = re.match(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            cur = m.group(1)
            buf = []
            blocks[cur] = buf
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                buf.append(line)
    return blocks


def _reach_multipliers(blocks: dict, text: str) -> dict:
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    edges = defaultdict(list)
    for name, lines in blocks.items():
        for line in lines:
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            for ref_kind in ("body=", "to_apply=", "calls=", "condition=",
                             "true_computation=", "false_computation="):
                for m in re.finditer(ref_kind + r"%?([\w.\-]+)", line):
                    mult = trip if ref_kind == "body=" else 1
                    edges[name].append((m.group(1), mult))
    mults = defaultdict(int)
    stack = [(entry, 1)] if entry in blocks else [(n, 1) for n in blocks]
    guard = 0
    while stack and guard < 200000:
        guard += 1
        comp, mult = stack.pop()
        if comp not in blocks:
            continue
        mults[comp] += mult
        for callee, m in edges.get(comp, []):
            stack.append((callee, mult * m))
    return mults


_DEF_RE = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+) = "
                     r"((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*)) (\w[\w\-]*)\(")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
# One operand: optional "f32[2,3]{1,0} " type prefix (newer XLA prints typed
# operand lists), then the %name.
_TYPED_OPERAND_RE = re.compile(
    r"^(?:(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%?([\w.\-]+)")


def _operand_list(line: str, opkind: str):
    """[(type_shape_or_None, name)] for the op's operands; shapes inline in
    the operand list (typed HLO) take precedence over name lookup."""
    m = re.search(re.escape(opkind) + r"\(([^)]*)\)", line)
    if not m:
        return []
    out = []
    for tok in m.group(1).split(", "):
        om = _TYPED_OPERAND_RE.match(tok.strip())
        if om:
            out.append((om.group(1), om.group(2)))
    return out

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy-done", "after-all", "partition-id"}

# HBM-traffic ops: outputs of these hit memory.  Bare elementwise ops
# (convert/add/select/...) are excluded — on TPU they fuse with a producer or
# consumer; XLA:CPU leaves many unfused which would overstate traffic ~10x.
_TRAFFIC_OPS = {"fusion", "dot", "convolution", "dynamic-update-slice",
                "dynamic-slice", "scatter", "gather", "copy", "copy-start",
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "sort", "reduce", "concatenate", "pad",
                "transpose", "reshape-and-transpose", "iota-nope"}


def hlo_cost(text: str) -> dict:
    """Trip-count-aware FLOPs/bytes from optimized HLO text.

    flops: 2 * |out| * K for every dot (K = product of lhs contracting dims),
    scaled by the enclosing computation's reach multiplier (scan bodies count
    x trip_count — XLA's own cost_analysis counts loop bodies once).
    bytes: 2 x sum of op output bytes (one write + roughly one read by a
    consumer) over non-trivial ops, same multipliers.  An approximation, but
    a consistent one for iterating on the memory term.
    """
    blocks = _computation_blocks(text)
    mults = _reach_multipliers(blocks, text)
    flops = 0.0
    bytes_ = 0.0
    for name, lines in blocks.items():
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        shapes = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            opname, shape_str, opkind = dm.groups()
            shapes[opname] = shape_str
            if opkind in _TRAFFIC_OPS:
                eff = shape_str
                if opkind in ("dynamic-update-slice", "scatter"):
                    # in-place ops only touch the UPDATE region, not the full
                    # buffer (a scan's residual stack would otherwise count
                    # trip_count x buffer): use the update operand's shape
                    # (operand 2 for DUS, operand 3 for scatter).
                    skip = 2 if opkind == "scatter" else 1
                    ops = _operand_list(line, opkind)
                    if len(ops) > skip:
                        tshape, opnd = ops[skip]
                        eff = tshape or shapes.get(opnd, eff)
                bytes_ += 2 * _shape_bytes(eff) * mult
            if opkind == "dot":
                cd = _DOT_DIMS_RE.search(line)
                ops = _operand_list(line, "dot")
                lhs_shape = None
                if ops:
                    tshape, opnd = ops[0]
                    lhs_shape = tshape or shapes.get(opnd)
                k = 1
                if cd and lhs_shape:
                    sm = _SHAPE_RE.search(lhs_shape)
                    if sm and sm.group(2):
                        dims = [int(x) for x in sm.group(2).split(",")]
                        for idx in (int(x) for x in cd.group(1).split(",") if x):
                            if idx < len(dims):
                                k *= dims[idx]
                out_elems = 0
                sm = _SHAPE_RE.search(shape_str)
                if sm:
                    n = 1
                    for d in (sm.group(2).split(",") if sm.group(2) else []):
                        n *= int(d)
                    out_elems = n
                flops += 2.0 * out_elems * k * mult
    return {"flops": flops, "bytes": bytes_}


def collective_stats(text: str, default_group: int = 1) -> CollectiveStats:
    blocks = _computation_blocks(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    # call graph with multipliers
    edges = defaultdict(list)  # comp -> [(callee, mult)]
    for name, lines in blocks.items():
        for line in lines:
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            for ref_kind in ("body=", "to_apply=", "calls=", "condition=",
                             "true_computation=", "false_computation="):
                for m in re.finditer(ref_kind + r"%?([\w.\-]+)", line):
                    mult = trip if ref_kind == "body=" else 1
                    edges[name].append((m.group(1), mult))

    # reach multipliers from entry
    mults = defaultdict(int)
    stack = [(entry, 1)] if entry in blocks else [(n, 1) for n in blocks]
    seen_depth = 0
    while stack and seen_depth < 200000:
        seen_depth += 1
        comp, mult = stack.pop()
        if comp not in blocks:
            continue
        mults[comp] += mult
        for callee, m in edges.get(comp, []):
            stack.append((callee, mult * m))

    bytes_by_kind = defaultdict(float)
    count_by_kind = defaultdict(int)
    for name, lines in blocks.items():
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            _, shape_str, kind, started = m.groups()
            if started and "-done" in line:
                continue
            size = _shape_bytes(shape_str)
            g = default_group
            gm = _GROUPSZ_RE.search(line)
            if gm:
                g = max(int(gm.group(2)), 1)
            else:
                gl = _GROUPLIST_RE.search(line)
                if gl:
                    g = len(gl.group(1).split(","))
            if kind == "all-reduce":
                size = 2 * size * (g - 1) / max(g, 1)
            elif kind == "all-gather":
                size = size * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                size = size * (g - 1)
            bytes_by_kind[kind] += size * mult
            count_by_kind[kind] += mult
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind))
