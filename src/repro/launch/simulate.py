"""Event-driven network simulation CLI for Q-GADMM (repro.sim).

Plays Q-GADMM out message-by-message over a modeled radio network and
reports wall-clock/Joules-to-target — the quantities the paper's headline
figures are about — under scenarios the lockstep benchmarks cannot
express: packet loss with retransmits, per-link latency/jitter,
heterogeneous compute, stragglers, worker drops, bounded-staleness
asynchrony.

  PYTHONPATH=src python -m repro.launch.simulate --topology ring --workers 8
  PYTHONPATH=src python -m repro.launch.simulate --topology star \\
      --censor --loss 0.05 --straggler 1:10 --bandwidth 2e6
  PYTHONPATH=src python -m repro.launch.simulate --async-staleness 2 \\
      --drop 2:40 --transport unicast --out sim.json
  PYTHONPATH=src python -m repro.launch.simulate --engine vectorized \\
      --topology cluster_of_stars --workers 10000 --participation 0.5 \\
      --loss 0.05 --latency 1e-3 --rounds 100 --no-record-states
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_model as cm
from repro.core import gadmm
from repro.core.censor import CensorConfig
from repro.core.quantizer import QuantizerConfig
from repro.core.topology import TOPOLOGY_KINDS
from repro.data.synthetic import regression_shards
from repro.sim import (ComputeModel, FaultPlan, NetworkConfig, SimConfig,
                       simulate)


def _parse_pairs(items, what: str) -> dict[int, float]:
    out = {}
    for item in items or []:
        try:
            k, v = item.split(":")
            out[int(k)] = float(v)
        except ValueError:
            raise SystemExit(f"bad --{what} spec {item!r}; expected "
                             f"WORKER:VALUE (e.g. 3:8)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="discrete-event Q-GADMM network simulation")
    ap.add_argument("--topology", default="chain", choices=list(TOPOLOGY_KINDS))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--dim", type=int, default=6)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--rho", type=float, default=24.0)
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--no-quantize", action="store_true",
                    help="full-precision GADMM wire (32*d bits/transmission)")
    ap.add_argument("--censor", action="store_true",
                    help="CQ-GGADMM censored transmissions")
    ap.add_argument("--censor-tau", type=float, default=0.05)
    ap.add_argument("--censor-xi", type=float, default=0.9)
    ap.add_argument("--bandwidth", type=float, default=2e6,
                    help="total system bandwidth in Hz (paper: 2 MHz)")
    ap.add_argument("--loss", type=float, default=0.0,
                    help="i.i.d. per-attempt packet loss probability")
    ap.add_argument("--latency", type=float, default=0.0,
                    help="per-link propagation latency (s)")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="uniform delivery jitter bound (s)")
    ap.add_argument("--transport", default="broadcast",
                    choices=["broadcast", "unicast"],
                    help="broadcast = paper radio; unicast = serialized "
                         "per-link sends (the trainer's port exchanges)")
    ap.add_argument("--compute", type=float, default=1e-3,
                    help="mean local compute time per phase (s)")
    ap.add_argument("--compute-jitter", type=float, default=0.0,
                    help="lognormal sigma of per-phase compute jitter")
    ap.add_argument("--straggler", action="append", default=None,
                    metavar="W:FACTOR",
                    help="slow worker W down by FACTOR (repeatable)")
    ap.add_argument("--drop", action="append", default=None,
                    metavar="W:ROUND",
                    help="worker W goes silent before round ROUND "
                         "(repeatable)")
    ap.add_argument("--join", action="append", default=None,
                    metavar="W:ROUND",
                    help="worker W joins at round ROUND (absent and silent "
                         "before it; repeatable)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-round Bernoulli participation rate in (0, 1]")
    ap.add_argument("--engine", default="events",
                    choices=["events", "vectorized"],
                    help="events = per-message loop (bitwise oracle); "
                         "vectorized = large-N array fast path "
                         "(graph mode, staleness 0, no drops)")
    ap.add_argument("--record-states", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="per-round state snapshots (--no-record-states "
                         "for large N; the objective trace is still "
                         "recorded)")
    ap.add_argument("--async-staleness", type=int, default=0,
                    help="bounded staleness S; 0 = barriered lockstep")
    ap.add_argument("--target", type=float, default=1e-4,
                    help="relative objective gap defining *-to-target")
    ap.add_argument("--fail-above", type=float, default=None, metavar="GAP",
                    help="exit nonzero unless the final relative objective "
                         "gap is <= GAP (CI convergence gate)")
    ap.add_argument("--seed", type=int, default=0)
    # NOT store_true: action="store_true" with default=True made the flag a
    # no-op AND --x64/--no-x64 an undetectable pair (the old bug); the
    # BooleanOptionalAction pair keeps both spellings working.
    ap.add_argument("--x64", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="run the simulator in float64 (exact paper math)")
    ap.add_argument("--out", default=None, help="write summary JSON here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write repro.obs/v1 JSONL run records here "
                         "(manifest, per-round records, summary)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the simulated timeline as a Chrome "
                         "trace-event file (one track per worker, "
                         "per-link flow arrows; Perfetto-loadable)")
    args = ap.parse_args(argv)

    if args.x64:
        jax.config.update("jax_enable_x64", True)
    n, d = args.workers, args.dim
    xs, ys, _ = regression_shards(n_workers=n, samples=args.samples, d=d,
                                  seed=args.seed)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    gcfg = gadmm.GADMMConfig(rho=args.rho, quantize=not args.no_quantize,
                             qcfg=QuantizerConfig(bits=args.bits))
    censor = (CensorConfig(tau=args.censor_tau, xi=args.censor_xi)
              if args.censor else None)
    scfg = SimConfig(
        topology=args.topology, rounds=args.rounds,
        staleness=args.async_staleness, seed=args.seed,
        participation=args.participation, engine=args.engine,
        record_states=args.record_states,
        radio=cm.RadioConfig(total_bandwidth_hz=args.bandwidth,
                             n_workers=n),
        network=NetworkConfig(latency_s=args.latency, jitter_s=args.jitter,
                              loss_prob=args.loss,
                              detection_delay_s=max(args.latency, 1e-3),
                              transport=args.transport),
        compute=ComputeModel(base_s=args.compute,
                             jitter_sigma=args.compute_jitter,
                             straggler=_parse_pairs(args.straggler,
                                                    "straggler")),
        faults=FaultPlan(
            drop_round={k: int(v) for k, v in
                        _parse_pairs(args.drop, "drop").items()},
            join_round={k: int(v) for k, v in
                        _parse_pairs(args.join, "join").items()}))
    res = simulate(xs, ys, gcfg, scfg, censor=censor)
    tt = res.to_rel_target(args.target)
    s = res.summary()
    skip = (1.0 - float(np.mean([st["sent"].mean() for st in res.states]))
            if res.states else 0.0)

    print(f"== repro.sim[{args.engine}]: {args.topology} x {n} workers, "
          f"{args.rounds} rounds, staleness {args.async_staleness}"
          + (f", participation {args.participation:g}"
             if args.participation < 1.0 else "") + " ==")
    print(f"  channel: {args.transport}, {args.bandwidth/1e6:g} MHz, "
          f"loss {args.loss:g}, latency {args.latency:g}s"
          + (", censored" if censor else ""))
    print(f"  events {s['events']}  makespan {s['makespan_s']:.4g}s  "
          f"energy {s['total_energy_j']:.4g}J  "
          f"wire {s['total_bits']:.4g}b  retx {s['retransmissions']}")
    print(f"  rounds completed: min {min(s['rounds_completed'])} "
          f"max {max(s['rounds_completed'])}"
          + (f"  dropped: {sorted(s['dropped'])}" if s["dropped"] else ""))
    if len(res.losses):
        print(f"  final relative gap: {res.final_rel_gap():.3e}"
              + (f"  censor skip rate: {skip:.2f}" if res.states else ""))
    print(f"  to {args.target:g} rel target: round {tt['round']:g}, "
          f"t={tt['time_s']:.4g}s, E={tt['energy_j']:.4g}J")
    per = s["per_worker_energy_j"]
    worst = int(np.argmax(per))
    print(f"  per-worker J: mean {np.mean(per):.3g}, "
          f"max {per[worst]:.3g} (worker {worst})")
    if args.metrics_out:
        from repro.obs import record
        manifest = record.manifest_record(
            scfg, seed=args.seed, topology=args.topology, num_workers=n,
            extra={"cli": "launch.simulate", "censored": censor is not None,
                   "quantized": not args.no_quantize, "bits": args.bits})
        times = res.timeline.global_round_times()
        with record.MetricsLog(path=args.metrics_out,
                               manifest=manifest) as mlog:
            for k, loss in enumerate(np.asarray(res.losses).tolist()):
                mlog.write(record.round_record(
                    k, t_s=(times[k] if k < len(times) else None),
                    loss=loss,
                    metrics={"energy_j": res.timeline.energy_until(times[k])
                             if k < len(times) else None}))
            mlog.close(summary={**s, "final_rel_gap":
                                (res.final_rel_gap()
                                 if len(res.losses) else None),
                                "to_target": tt})
        print(f"wrote {args.metrics_out}")
    trace_events = None
    if args.trace:
        from repro.obs import trace as obs_trace
        trace_events = obs_trace.timeline_trace(res.timeline)
        obs_trace.write_trace(args.trace, trace_events)
        print(f"wrote {args.trace} ({len(trace_events)} events)")
    from repro.obs import checks
    if checks.enabled():
        checks.check_timeline(res.timeline)
        if trace_events is not None:
            checks.check_trace(trace_events, res.timeline)
        print("REPRO_CHECK: timeline conservation"
              + (" + trace accounting" if trace_events is not None else "")
              + " OK")
    if args.out:
        s.update(topology=args.topology, workers=n,
                 staleness=args.async_staleness, loss=args.loss,
                 bandwidth_hz=args.bandwidth, transport=args.transport,
                 censored=censor is not None, engine=args.engine,
                 participation=args.participation,
                 final_rel_gap=(res.final_rel_gap()
                                if len(res.losses) else None),
                 to_target=tt)
        with open(args.out, "w") as f:
            json.dump(s, f, indent=1, default=str)
        print(f"wrote {args.out}")
    if args.fail_above is not None:
        if not len(res.losses):
            print("--fail-above needs an objective trace", file=sys.stderr)
            return 2
        gap = res.final_rel_gap()
        if not np.isfinite(gap) or gap > args.fail_above:
            print(f"FAIL: final relative gap {gap:.3e} > "
                  f"{args.fail_above:g}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
