import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and extract the roofline
terms.  No real allocation: all inputs are ShapeDtypeStructs.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch ... --multi-pod
"""
import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.censor import CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import LayerwiseConfig, QuantizerConfig
from repro.core.topology import TOPOLOGY_KINDS
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
from repro.dist.serve import Server, cache_specs, serve_view
from repro.launch import hlo_stats
from repro.launch.mesh import factor_mesh, make_production_mesh
from repro.models import registry
from repro.models.config import num_active_params, num_params

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# long_500k requires sub-quadratic attention / bounded state (DESIGN.md):
LONG_OK = {"mamba2-2.7b", "zamba2-2.7b", "gemma3-27b"}

# v5e hardware constants (roofline):
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link


def pick_workers(arch: str, total_data: int) -> int:
    """GADMM worker count: as decentralized as memory allows (DESIGN.md)."""
    n = num_params(registry.get_config(arch))
    if n > 50e9:
        return min(2, total_data)
    if n > 10e9:
        return min(4, total_data)
    return min(16, total_data)


def input_specs(cfg, shape_name: str, num_workers: int = 0):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    sh = SHAPES[shape_name]
    seq, batch = sh["seq"], sh["batch"]
    sds = jax.ShapeDtypeStruct
    if sh["kind"] == "train":
        w = num_workers
        per = batch // w
        b = {"tokens": sds((w, per, seq), jnp.int32),
             "labels": sds((w, per, seq), jnp.int32)}
        if cfg.family == "vlm":
            b["patches"] = sds((w, per, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            b["frames"] = sds((w, per, cfg.encoder_frames, cfg.d_model),
                              jnp.float32)
        return b
    if sh["kind"] == "prefill":
        b = {"tokens": sds((batch, seq), jnp.int32)}
        if cfg.family == "vlm":
            b["patches"] = sds((batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            b["frames"] = sds((batch, cfg.encoder_frames, cfg.d_model),
                              jnp.float32)
        return b
    # decode: token + pos (+ cache handled separately)
    return {"token": sds((batch,), jnp.int32),
            "pos": sds((batch,), jnp.int32)}


def _roofline(cost, coll_bytes: float, n_chips: int, cfg, shape_name):
    """`cost` comes from hlo_stats.hlo_cost (trip-count-aware, per-device
    partitioned program).  XLA's compiled.cost_analysis counts while-loop
    bodies ONCE, so it is only printed as a cross-check."""
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes", 0.0)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    # collective bytes parsed per-device program; 1 link assumed busy
    collective_s = coll_bytes / ICI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dom = max(terms, key=terms.get)
    n_active = num_active_params(cfg)
    sh = SHAPES[shape_name]
    tokens = sh["batch"] * (sh["seq"] if sh["kind"] != "decode" else 1)
    if sh["kind"] == "train":
        model_flops = 6 * n_active * tokens  # fwd + bwd
    else:
        model_flops = 2 * n_active * tokens  # fwd only (prefill / decode)
    total_hlo_flops = flops * n_chips
    return dict(
        **terms, dominant=dom,
        hlo_flops_per_device=flops, hlo_bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_bytes,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_hlo_flops
                            if total_hlo_flops else 0.0),
    )


def dryrun_train(arch: str, shape_name: str, *, multi_pod: bool,
                 mode: str = "gauss-seidel", workers: int = 0,
                 quantize: bool = True, local_iters: int = 1,
                 microbatches: int = 1, verbose: bool = True,
                 xent: str = "gather", attn_remat: bool = False,
                 uneven: bool = False, pack: bool | None = None,
                 bits: int = 8, seq_shard: bool = False,
                 wire_impl: str = "jnp", reduced: bool = False,
                 topology: str = "chain",
                 censor: CensorConfig | None = None,
                 staleness: int = 0, participation: float = 1.0,
                 layerwise: LayerwiseConfig | None = None):
    cfg = registry.get_config(
        arch, smoke=reduced, compute_dtype=jnp.bfloat16,
        param_dtype=jnp.float32, xent_mode=xent, attn_scan_remat=attn_remat,
        head_pad=16 if uneven else 0)
    model = registry.get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod, reduced=reduced)
    total_data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    w = workers or pick_workers(arch, total_data)
    if multi_pod and w < mesh.shape["pod"]:
        w = mesh.shape["pod"]
    wmesh = factor_mesh(mesh, w)
    dcfg = DistConfig(
        num_workers=w,
        gadmm=GADMMConfig(rho=1.0, quantize=quantize,
                          qcfg=QuantizerConfig(bits=bits), alpha=0.01),
        local_iters=local_iters, microbatches=microbatches, mode=mode,
        state_dtype=jnp.bfloat16, uneven_shard=uneven, pack_wire=pack,
        seq_shard=seq_shard, wire_impl=wire_impl, topology=topology,
        censor=censor, staleness=staleness, participation=participation,
        layerwise=layerwise)
    trainer = QGADMMTrainer(model, cfg, dcfg, wmesh)
    state_structs = jax.eval_shape(
        functools.partial(init_state,
                          lambda k: model.init(k, cfg), dcfg=dcfg),
        jax.ShapeDtypeStruct((2,), jax.random.PRNGKey(0).dtype))
    batch_structs = input_specs(cfg, shape_name, num_workers=w)
    t0 = time.time()
    jitted = trainer.jit_train_step(state_structs, batch_structs)
    lowered = jitted.lower(state_structs, batch_structs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return _report(compiled, wmesh, cfg, shape_name, arch,
                   dict(mode=mode, workers=w, quantize=quantize,
                        t_lower=t_lower, t_compile=t_compile,
                        reduced=reduced, wire_impl=wire_impl,
                        topology=topology, censor=censor is not None,
                        staleness=staleness,
                        layerwise=layerwise is not None),
                   verbose=verbose)


def dryrun_serve(arch: str, shape_name: str, *, multi_pod: bool,
                 verbose: bool = True, windowed_cache: bool = False,
                 reduced: bool = False):
    cfg = registry.get_config(
        arch, smoke=reduced, compute_dtype=jnp.bfloat16,
        param_dtype=jnp.bfloat16)
    model = registry.get_model(cfg)
    sh = SHAPES[shape_name]
    mesh = serve_view(make_production_mesh(multi_pod=multi_pod,
                                           reduced=reduced))
    server = Server(model=model, cfg=cfg, mesh=mesh, batch_size=sh["batch"])
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    t0 = time.time()
    if sh["kind"] == "prefill":
        batch = input_specs(cfg, shape_name)
        jitted = server.jit_prefill(params, batch, sh["batch"])
        lowered = jitted.lower(params, batch)
    else:
        if cfg.family == "ssm":
            cache = jax.eval_shape(
                lambda: model.init_cache(cfg, sh["batch"], dtype=jnp.bfloat16))
        elif (windowed_cache and cfg.family == "dense" and cfg.global_every
              and cfg.sliding_window):
            from repro.models import dense as _dense

            cache = jax.eval_shape(
                lambda: _dense.init_cache_windowed(cfg, sh["batch"], sh["seq"],
                                                   dtype=jnp.bfloat16))
        else:
            cache = jax.eval_shape(
                lambda: model.init_cache(cfg, sh["batch"], sh["seq"],
                                         dtype=jnp.bfloat16))
        io = input_specs(cfg, shape_name)
        jitted = server.jit_decode(params, cache, sh["batch"],
                                   seq_parallel=(sh["batch"] == 1))
        lowered = jitted.lower(params, io["token"], cache, io["pos"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return _report(compiled, mesh, cfg, shape_name, arch,
                   dict(t_lower=t_lower, t_compile=t_compile,
                        reduced=reduced),
                   verbose=verbose)


SAVE_HLO_DIR = os.environ.get("REPRO_SAVE_HLO", "")


def _report(compiled, mesh, cfg, shape_name, arch, extra, verbose=True):
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover
        cost = {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = getattr(ma, k)
    except Exception:
        pass
    text = compiled.as_text()
    if SAVE_HLO_DIR:
        import gzip

        os.makedirs(SAVE_HLO_DIR, exist_ok=True)
        tag = "x".join(str(v) for v in mesh.shape.values())
        with gzip.open(os.path.join(
                SAVE_HLO_DIR, f"{arch}_{shape_name}_{tag}.hlo.gz"), "wt") as f:
            f.write(text)
    coll = hlo_stats.collective_stats(text)
    walked = hlo_stats.hlo_cost(text)
    roof = _roofline(walked, coll.total_bytes, n_chips, cfg, shape_name)
    result = dict(arch=arch, shape=shape_name, mesh=dict(mesh.shape),
                  chips=n_chips, collectives=coll.bytes_by_kind,
                  collective_counts=coll.count_by_kind, memory=mem,
                  xla_cost_flops=(cost or {}).get("flops", 0.0),
                  **roof, **extra)
    if verbose:
        hbm_need = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
        print(f"== {arch} x {shape_name} on {dict(mesh.shape)} ==")
        print(f"  lower {extra.get('t_lower', 0):.1f}s  "
              f"compile {extra.get('t_compile', 0):.1f}s")
        print(f"  memory_analysis: {mem} (~{hbm_need/1e9:.2f} GB/device)")
        print(f"  cost_analysis: flops/device={roof['hlo_flops_per_device']:.3e} "
              f"bytes/device={roof['hlo_bytes_per_device']:.3e}")
        print(f"  collectives: { {k: f'{v/1e6:.1f}MB' for k, v in coll.bytes_by_kind.items()} }")
        print(f"  roofline: compute={roof['compute_s']*1e3:.2f}ms "
              f"memory={roof['memory_s']*1e3:.2f}ms "
              f"collective={roof['collective_s']*1e3:.2f}ms "
              f"-> dominant: {roof['dominant']}")
        print(f"  MODEL_FLOPS/HLO_FLOPS = {roof['useful_flops_ratio']:.3f}")
    return result


def iter_pairs():
    for arch in registry.ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--mode", default="gauss-seidel")
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--local-iters", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--xent", default="onehot", choices=["gather", "onehot"])
    # BooleanOptionalAction, not store_true+default=True: the latter makes
    # the positive flag a silent no-op (same bug class as simulate.py --x64)
    ap.add_argument("--attn-remat", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--uneven", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="pad non-divisible MHA head counts (exact; masked)")
    ap.add_argument("--pack", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="force int4 wire packing on (--no-pack forces off; "
                         "default None = DistConfig auto: packed iff "
                         "effective bits <= 4)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residual stream (train)")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--wire-impl", default="jnp",
                    choices=["jnp", "pallas", "pallas_compiled"],
                    help="fused wire-path codec (dist.qgadmm wire_impl)")
    ap.add_argument("--topology", default="chain", choices=list(TOPOLOGY_KINDS),
                    help="worker graph for the train pairs (ring needs even "
                         "workers, torus2d needs workers %% 4 == 0)")
    ap.add_argument("--censor", action="store_true",
                    help="enable CQ-GGADMM censored transmissions "
                         "(--censor-tau/--censor-xi thresholds)")
    ap.add_argument("--censor-tau", type=float, default=0.05)
    ap.add_argument("--censor-xi", type=float, default=0.9)
    ap.add_argument("--staleness", type=int, default=0,
                    help="S>0 compiles the pipelined exchange (send / "
                         "recv-start / recv-done over an S-deep in-flight "
                         "ring) instead of the per-color barrier")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="<1 compiles the partial-participation step "
                         "(per-round Bernoulli masks, renormalized "
                         "neighbor sums)")
    ap.add_argument("--layerwise", action="store_true",
                    help="L-FGADMM per-leaf wire: large leaves transmit "
                         "every --layerwise-period rounds at per-leaf bit "
                         "widths (DistConfig.layerwise)")
    ap.add_argument("--layerwise-period", type=int, default=2,
                    help="exchange period of the large leaves (top half "
                         "of the model by parameter count)")
    ap.add_argument("--bit-budget", type=int, default=None, metavar="BITS",
                    help="adaptive per-leaf bit allocation under a fixed "
                         "sum(bits_l * d_l) payload budget per "
                         "transmission (implies --layerwise)")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke configs on 16-device meshes: records the "
                         "full 33-pair matrix on CPU (committed artifacts)")
    ap.add_argument("--windowed-cache", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--paper-baseline", action="store_true",
                    help="disable every §Perf optimization (baseline tables)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export per-pair lower/compile wall-time spans as "
                         "a Chrome trace-event file (Perfetto-loadable)")
    args = ap.parse_args(argv)
    if args.paper_baseline:
        args.xent, args.attn_remat, args.uneven = "gather", False, False
        args.windowed_cache = False
    if args.reduced:
        # smoke dims (e.g. the tiny vocab) are not GSPMD-pad-shardable, so
        # the uneven-head toggle is meaningless at smoke scale
        args.uneven = False

    results = []
    pairs = (list(iter_pairs()) if args.all
             else [(args.arch, args.shape)])
    for arch, shape in pairs:
        kind = SHAPES[shape]["kind"]
        try:
            if kind == "train":
                r = dryrun_train(arch, shape, multi_pod=args.multi_pod,
                                 mode=args.mode, workers=args.workers,
                                 quantize=not args.no_quantize,
                                 local_iters=args.local_iters,
                                 microbatches=args.microbatches,
                                 xent=args.xent, attn_remat=args.attn_remat,
                                 uneven=args.uneven, pack=args.pack,
                                 bits=args.bits, seq_shard=args.seq_shard,
                                 wire_impl=args.wire_impl,
                                 reduced=args.reduced,
                                 topology=args.topology,
                                 censor=(CensorConfig(tau=args.censor_tau,
                                                      xi=args.censor_xi)
                                         if args.censor else None),
                                 staleness=args.staleness,
                                 participation=args.participation,
                                 layerwise=(LayerwiseConfig(
                                     large_leaf_period=args.layerwise_period,
                                     budget_bits=args.bit_budget)
                                     if args.layerwise
                                     or args.bit_budget is not None
                                     else None))
            else:
                r = dryrun_serve(arch, shape, multi_pod=args.multi_pod,
                                 windowed_cache=args.windowed_cache,
                                 reduced=args.reduced)
            results.append(r)
        except Exception as e:
            print(f"== {arch} x {shape} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            results.append(dict(arch=arch, shape=shape, error=str(e)))
            if not args.all:
                raise
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.out}")
    if args.trace:
        # compile-time capture: one lower + one compile span per pair,
        # laid end to end (the pairs ran sequentially above)
        from repro.obs import trace as obs_trace
        events = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
                   "args": {"name": "dryrun"}}]
        ts = 0.0
        for r in results:
            if "error" in r:
                continue
            for field, label in (("t_lower", "lower"),
                                 ("t_compile", "compile")):
                dur = float(r.get(field, 0.0)) * 1e6
                events.append({
                    "name": f"{r['arch']}/{r['shape']}:{label}",
                    "ph": "X", "pid": 1, "tid": 0, "ts": ts, "dur": dur,
                    "args": {"arch": r["arch"], "shape": r["shape"],
                             "seconds": float(r.get(field, 0.0))}})
                ts += dur
        obs_trace.write_trace(args.trace, events)
        print(f"wrote {args.trace}")
    ok = sum(1 for r in results if "error" not in r)
    print(f"{ok}/{len(results)} pairs compiled successfully")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
