"""Serving launcher: batched prefill + decode on a (emulated or real) mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --devices 8 --batch 4 --prompt-len 32 --gen-len 16
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    # --smoke was store_true+default=True (a no-op flag); keep --full as the
    # established negative spelling alongside the generated --no-smoke
    ap.add_argument("--smoke", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.dist.serve import Server, serve_view
    from repro.models import registry

    devices = np.array(jax.devices())
    d = max(1, args.devices // 2)
    mesh = serve_view(Mesh(devices[: d * 2].reshape(d, 2), ("data", "model")))
    print(f"mesh: {dict(mesh.shape)}")

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    model = registry.get_model(cfg)
    server = Server(model=model, cfg=cfg, mesh=mesh, batch_size=args.batch)
    params = model.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, server.param_shardings(params))

    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_frames, cfg.d_model))

    t0 = time.time()
    prefill = server.jit_prefill(params, batch, args.batch)
    logits, cache = prefill(params, batch)
    print(f"prefill({args.prompt_len}) in {time.time()-t0:.2f}s "
          f"logits sharding: {logits.sharding.spec}")

    max_seq = args.prompt_len + args.gen_len
    npatch = cfg.n_patches if cfg.family == "vlm" else 0
    if "k" in cache and cfg.family not in ("hybrid", "ssm"):
        pad = max_seq + npatch - cache["k"].shape[-3]
        if pad > 0:
            w = [(0, 0)] * (cache["k"].ndim - 3) + [(0, pad), (0, 0), (0, 0)]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], w)
            cache["v"] = jnp.pad(cache["v"], w)

    decode = server.jit_decode(params, cache, args.batch)
    tok = jnp.argmax(logits, axis=-1)
    t0 = time.time()
    for i in range(args.gen_len):
        pos = jnp.full((args.batch,), args.prompt_len + i + npatch, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, axis=-1)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.gen_len} steps x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch*args.gen_len/dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
