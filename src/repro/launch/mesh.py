"""Production mesh construction.

make_production_mesh is a FUNCTION (importing this module never touches jax
device state).  The canonical axes are ('data','model') single-pod and
('pod','data','model') multi-pod; Q-GADMM views the same devices through a
factored ('worker','fsdp','model') mesh: the worker axis carries the GADMM
chain (pods fold into it on multi-pod meshes), the fsdp axis shards each
worker's state, the model axis is tensor/expert parallel.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False, reduced: bool = False):
    """Canonical 256/512-chip meshes; reduced=True gives the same topology at
    16-device scale (CPU-recordable dry-run sweeps, see launch.dryrun)."""
    if reduced:
        shape = (2, 4, 2) if multi_pod else (8, 2)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def factor_mesh(mesh: Mesh, num_workers: int) -> Mesh:
    """View `mesh` as ('worker','fsdp','model').

    Single-pod (data, model): data = num_workers * fsdp.
    Multi-pod (pod, data, model): pod*data = num_workers * fsdp, pods are the
    leading factor of the worker axis (pod boundaries = worker boundaries when
    num_workers >= n_pods, the flagship cross-pod Q-GADMM configuration).
    """
    devices = mesh.devices
    if devices.ndim == 3:  # (pod, data, model)
        p, d, m = devices.shape
        total = p * d
    else:
        d, m = devices.shape
        total = d
    if total % num_workers:
        raise ValueError(f"num_workers={num_workers} must divide {total}")
    fsdp = total // num_workers
    return Mesh(devices.reshape(num_workers, fsdp, m),
                ("worker", "fsdp", "model"))


def serve_mesh(mesh: Mesh) -> Mesh:
    """Serving view: ('data','model') with pods folded into data."""
    devices = mesh.devices
    if devices.ndim == 3:
        p, d, m = devices.shape
        return Mesh(devices.reshape(p * d, m), ("data", "model"))
    return mesh
