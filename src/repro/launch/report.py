"""Summarize / diff repro.obs JSONL runs — the bench-regression triage
tool.

  PYTHONPATH=src python -m repro.launch.report RUN.jsonl
  PYTHONPATH=src python -m repro.launch.report A.jsonl B.jsonl --target 1e-4

One run: prints the manifest provenance and the headline statistics
(rounds-to-target, bits/round percentiles, skip rate, step-time
percentiles).  Two runs: the same rows side by side with the B/A ratio —
a wire-bits or step-time regression shows up as a ratio, not as two
walls of JSON to eyeball.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.obs.record import validate_run


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) else None


def summarize(recs: list[dict], target: float | None = None) -> dict:
    """Headline statistics of one validated run (manifest + records)."""
    man = recs[0]
    steps = [r for r in recs if r["kind"] == "step"]
    rounds = [r for r in recs if r["kind"] == "round"]
    summaries = [r["summary"] for r in recs if r["kind"] == "summary"]

    def metric(name):
        return [r["metrics"][name] for r in steps
                if isinstance(r["metrics"].get(name), (int, float))]

    out: dict = {
        "records": len(recs),
        "steps": len(steps),
        "rounds": len(rounds),
    }
    bits = metric("wire_bits_per_round")
    if bits:
        out["wire_bits_p50"] = _pct(bits, 50)
        out["wire_bits_p90"] = _pct(bits, 90)
    skip = metric("skip_rate")
    if skip:
        out["skip_rate_mean"] = float(np.mean(skip))
    loss = metric("loss")
    if loss:
        out["loss_first"], out["loss_last"] = loss[0], loss[-1]
    wall = [r["wall_s"] for r in steps
            if isinstance(r.get("wall_s"), (int, float))]
    if wall:
        out["step_s_p50"] = _pct(wall, 50)
        out["step_s_p90"] = _pct(wall, 90)
    if rounds:
        rl = [(r["round"], r.get("loss"), r.get("t_s")) for r in rounds]
        losses = [l for _, l, _ in rl if isinstance(l, (int, float))]
        if losses:
            out["loss_last"] = losses[-1]
        if target is not None:
            hit = next((r for r in rounds
                        if isinstance(r.get("loss"), (int, float))
                        and r["loss"] <= target), None)
            out["rounds_to_target"] = (
                float(hit["round"] + 1) if hit else float("inf"))
            if hit and isinstance(hit.get("t_s"), (int, float)):
                out["time_to_target_s"] = hit["t_s"]
    if target is not None and loss:
        hit = next((r for r in steps
                    if isinstance(r["metrics"].get("loss"), (int, float))
                    and r["metrics"]["loss"] <= target), None)
        out["rounds_to_target"] = (float(hit["step"] + 1) if hit
                                   else float("inf"))
    for s in summaries:
        for k in ("total_bits", "total_energy_j", "makespan_s",
                  "s_per_step", "final_rel_gap"):
            if isinstance(s.get(k), (int, float)):
                out[k] = s[k]
        tt = s.get("to_target")
        if isinstance(tt, dict) and "round" in tt:
            out.setdefault("rounds_to_target", tt["round"])
    out["_provenance"] = {
        "config_hash": man.get("config_hash"),
        "git_sha": man.get("git_sha"),
        "topology": man.get("topology"),
        "seed": man.get("seed"),
        "cli": man.get("cli"),
    }
    return out


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def print_report(a: dict, b: dict | None = None) -> None:
    keys = [k for k in a if not k.startswith("_")]
    if b is not None:
        keys += [k for k in b if not k.startswith("_") and k not in keys]
    width = max(len(k) for k in keys) + 2
    if b is None:
        for k in keys:
            print(f"  {k:<{width}} {_fmt(a.get(k))}")
        return
    print(f"  {'':<{width}} {'A':>12} {'B':>12} {'B/A':>8}")
    for k in keys:
        va, vb = a.get(k), b.get(k)
        ratio = "-"
        if isinstance(va, (int, float)) and isinstance(vb, (int, float)) \
                and va not in (0, float("inf")) and np.isfinite(va) \
                and np.isfinite(vb):
            ratio = f"{vb / va:.3f}"
        print(f"  {k:<{width}} {_fmt(va):>12} {_fmt(vb):>12} {ratio:>8}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize / compare repro.obs JSONL runs")
    ap.add_argument("runs", nargs="+", metavar="RUN.jsonl",
                    help="one run to summarize, or two to diff (A B)")
    ap.add_argument("--target", type=float, default=None,
                    help="loss target defining rounds-to-target")
    args = ap.parse_args(argv)
    if len(args.runs) > 2:
        ap.error("expected one or two runs")

    loaded = []
    for path in args.runs:
        recs = validate_run(path)
        loaded.append((path, summarize(recs, target=args.target)))

    for path, s in loaded:
        p = s["_provenance"]
        topo = p.get("topology") or {}
        print(f"== {path}: {p.get('cli') or 'run'} "
              f"cfg={p.get('config_hash')} git={p.get('git_sha')} "
              f"topo={topo.get('kind')}x{topo.get('num_workers')} "
              f"seed={p.get('seed')} ==")
    a = loaded[0][1]
    if len(loaded) == 1:
        print_report(a)
        return 0
    b = loaded[1][1]
    if a["_provenance"]["config_hash"] != b["_provenance"]["config_hash"]:
        print("  note: different config hashes — comparing across configs")
    print_report(a, b)
    return 0


if __name__ == "__main__":
    sys.exit(main())
