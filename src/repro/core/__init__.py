"""Core: the paper's contribution — Q-GADMM / Q-SGADMM and their baselines,
plus the CQ-GGADMM extensions (generalized bipartite topologies + censored
transmissions)."""
from .censor import CensorConfig
from .gadmm import (ChainState, GADMMConfig, GraphState, Quadratic,
                    bits_per_round, dequantize_rows, gadmm_step,
                    graph_bits_per_round, graph_consts, graph_dual_update,
                    graph_init_state, graph_phase, graph_step, init_state,
                    make_graph_quadratic, make_quadratic, quantize_rows)
from .quantizer import (LayerwiseConfig, QuantizerConfig, QuantState,
                        allocate_bits, dequantize, payload_bits, quantize)
from .sgadmm import SGADMMConfig, SGADMMTrainer
from .topology import (Placement, Topology, build_topology, chain_topology,
                       random_placement, ring_topology, star_topology,
                       torus2d_topology)

__all__ = [
    "ChainState", "GADMMConfig", "Quadratic", "bits_per_round", "gadmm_step",
    "init_state", "make_quadratic", "LayerwiseConfig", "QuantizerConfig",
    "QuantState", "allocate_bits",
    "dequantize", "payload_bits", "quantize", "SGADMMConfig", "SGADMMTrainer",
    "CensorConfig", "GraphState", "dequantize_rows", "graph_bits_per_round",
    "graph_consts", "graph_dual_update", "graph_init_state", "graph_phase",
    "graph_step", "make_graph_quadratic", "quantize_rows", "Placement",
    "Topology",
    "build_topology", "chain_topology", "random_placement", "ring_topology",
    "star_topology", "torus2d_topology",
]
