"""Core: the paper's contribution — Q-GADMM / Q-SGADMM and their baselines."""
from .gadmm import (ChainState, GADMMConfig, Quadratic, bits_per_round,
                    gadmm_step, init_state, make_quadratic)
from .quantizer import (QuantizerConfig, QuantState, dequantize, payload_bits,
                        quantize)
from .sgadmm import SGADMMConfig, SGADMMTrainer

__all__ = [
    "ChainState", "GADMMConfig", "Quadratic", "bits_per_round", "gadmm_step",
    "init_state", "make_quadratic", "QuantizerConfig", "QuantState",
    "dequantize", "payload_bits", "quantize", "SGADMMConfig", "SGADMMTrainer",
]
