"""Stochastic quantizer of Q-GADMM (paper eqs. 6-13).

Worker n at iteration k quantizes the *difference* between its current model
theta_n^k and its previously-quantized model theta_hat_n^{k-1}:

    R      = ||theta - theta_hat_prev||_inf                 (quantization radius)
    Delta  = 2 R / (2^b - 1)                                (step size)
    c_i    = (theta_i - theta_hat_prev_i + R) / Delta       (non-negative coords)
    q_i    = ceil(c_i)  w.p.  c_i - floor(c_i)              (stochastic rounding,
             floor(c_i) otherwise                            eq. 7 + eq. 10)
    theta_hat = theta_hat_prev + Delta * q - R * 1          (reconstruction, eq. 13)

The rounding probability choice makes E[theta_hat] = theta (unbiased, eq. 8)
with per-coordinate variance <= Delta^2 / 4.

The payload actually transmitted is (q:int levels, R:f32, b:int) ->
b*d + 32 + 32 bits instead of 32*d bits for a full-precision vector; see
header_bits / payload_bits (the same accounting rule backs
gadmm.bits_per_round and the distributed trainer's metrics).

Everything here is pure JAX and jit/vmap/pjit friendly.  A fused Pallas TPU
kernel for the same computation lives in repro/kernels/quantize (ops.q_dequantize
dispatches to it when enabled).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """Static configuration of the stochastic quantizer.

    bits:       quantizer resolution b (levels = 2^b - 1 intervals).  The paper
                uses b=2 for linear regression and b=8 for the DNN task.
    adapt_bits: if True, apply the bit-growth rule (eq. 11) that keeps
                Delta_n^k non-increasing.  The paper observes R_n^k decreases in
                practice so fixed bits suffice; both modes are supported.
    max_bits:   cap for adaptive bits (payload dtype is int8 / packed int4).

    (Tighter-than-global ranges are provided by the distributed trainer's
    radius_mode='per_tensor'; see repro.dist.qgadmm.)
    """

    bits: int = 2
    adapt_bits: bool = False
    max_bits: int = 8

    def __post_init__(self):
        assert 1 <= self.bits <= self.max_bits <= 8


@dataclasses.dataclass(frozen=True)
class LayerwiseConfig:
    """Per-leaf (L-FGADMM, arXiv:1911.03654) quantization knobs.

    Attached as DistConfig.layerwise; the distributed trainer resolves every
    field against the model's flat leaf list (resolve()) and gives each
    pytree leaf its own bit width, exchange period and censor threshold.
    An unsent leaf rides the wire with radius 0 — the codec's R == 0 guard
    makes it a bitwise no-op on both endpoints, so receivers hold the leaf's
    last hat and the sender==receiver sync invariant survives.

    bits:       per-leaf base bit widths — an int (all leaves), a tuple of
                length L (leaf order = jax.tree.leaves), or None (fall back
                to QuantizerConfig.bits).
    periods:    per-leaf exchange periods — int or length-L tuple; leaf l is
                transmitted only on rounds where step % periods[l] == 0.
    large_leaf_period / large_leaf_frac: size-based period rule for CLI use
                (tuples don't fit on a command line): any leaf holding at
                least large_leaf_frac of the total parameters gets period
                large_leaf_period.  An explicit `periods` tuple wins.
    taus:       optional per-leaf censor thresholds (L2, like
                censor.CensorConfig.tau but per leaf) — float or length-L
                tuple; leaf l is transmitted only when its committed
                quantized delta moved more than taus[l] * tau_xi**step.
    tau_xi:     decay of the per-leaf thresholds (CQ-GGADMM's xi).
    adapt_bits: apply the eq. 11 bit-growth rule per leaf (each leaf tracks
                its own radius ratio; first transmission falls back to the
                leaf's base bits).
    budget_bits: total payload-bit budget per worker per round for the
                adaptive bit-budget controller (allocate_bits): each round
                the budget is reallocated toward the leaves whose quantized
                deltas moved most.  When set it supersedes the static /
                eq. 11 widths — the controller is itself adaptive.  None
                disables the controller.
    min_bits / max_bits: controller range (and eq. 11 cap).
    """

    bits: Any = None
    periods: Any = 1
    large_leaf_period: int = 1
    large_leaf_frac: float = 0.5
    taus: Any = None
    tau_xi: float = 1.0
    adapt_bits: bool = False
    budget_bits: int | None = None
    min_bits: int = 1
    max_bits: int = 8

    def __post_init__(self):
        assert 1 <= self.min_bits <= self.max_bits <= 8
        assert self.large_leaf_period >= 1
        assert 0.0 < self.large_leaf_frac <= 1.0
        assert 0.0 < self.tau_xi <= 1.0
        assert self.budget_bits is None or self.budget_bits > 0
        for name in ("bits", "periods"):
            v = getattr(self, name)
            if isinstance(v, int):
                assert v >= 1, (name, v)
            elif v is not None:
                assert all(int(b) >= 1 for b in v), (name, v)
        if isinstance(self.bits, int):
            assert self.bits <= self.max_bits

    def _expand(self, value, sizes, default):
        n = len(sizes)
        if value is None:
            value = default
        if isinstance(value, (int, float)):
            return [value] * n
        assert len(value) == n, (
            f"layerwise field of length {len(value)} vs {n} leaves")
        return list(value)

    def resolve(self, sizes, base_bits: int):
        """Per-leaf tables for a model with flat leaf sizes `sizes`.

        Returns (bits, periods, taus): int lists of length L (taus None when
        no per-leaf censoring is configured).  Pure-python/static — the
        trainer bakes the result into the compiled step.
        """
        bits = [int(b) for b in self._expand(self.bits, sizes, base_bits)]
        assert all(1 <= b <= self.max_bits for b in bits), bits
        periods = [int(p) for p in self._expand(self.periods, sizes, 1)]
        if self.large_leaf_period > 1 and not isinstance(
                self.periods, (tuple, list)):
            total = max(sum(sizes), 1)
            periods = [self.large_leaf_period
                       if s >= self.large_leaf_frac * total else p
                       for p, s in zip(periods, sizes)]
        taus = (None if self.taus is None
                else [float(t) for t in self._expand(self.taus, sizes, 0.0)])
        return bits, periods, taus


def allocate_bits(scores: Array, sizes: Array, budget_bits: int,
                  min_bits: int, max_bits: int) -> Array:
    """Adaptive bit-budget controller: spend `budget_bits` of payload on the
    leaves whose quantized deltas moved most.

    scores: (..., L) per-leaf residual magnitudes (any nonnegative ranking
      score; the trainer uses the per-leaf L2 of theta - theta_hat, the same
      quantity the censoring rule thresholds).
    sizes:  (L,) static per-leaf element counts.
    Returns (..., L) int32 bit widths with min_bits <= b_l <= max_bits and
      sum_l b_l * sizes_l <= max(budget_bits, min_bits * sum(sizes)) — every
      leaf is floored at min_bits (the floor is spent even when the budget
      cannot cover it), and the remaining budget upgrades leaves in strict
      score order: a leaf is upgraded as far as the budget left over after
      fully upgrading every better-ranked leaf allows.
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    scores = jnp.asarray(scores, jnp.float32)
    span = float(max_bits - min_bits)
    avail = jnp.maximum(
        float(budget_bits) - float(min_bits) * jnp.sum(sizes), 0.0)
    order = jnp.argsort(-scores, axis=-1)                       # best first
    cost = jnp.broadcast_to(span * sizes, scores.shape)
    cost_sorted = jnp.take_along_axis(cost, order, axis=-1)
    spent_before = jnp.cumsum(cost_sorted, axis=-1) - cost_sorted
    room = jnp.maximum(avail - spent_before, 0.0)
    add_sorted = jnp.clip(
        jnp.floor(room / jnp.maximum(
            jnp.take_along_axis(
                jnp.broadcast_to(sizes, scores.shape), order, axis=-1),
            1.0)),
        0.0, span)
    inv = jnp.argsort(order, axis=-1)
    add = jnp.take_along_axis(add_sorted, inv, axis=-1)
    return (min_bits + add).astype(jnp.int32)


@dataclasses.dataclass
class QuantState:
    """Carried across iterations for one worker's tensor (pytree)."""

    theta_hat: Any  # previously quantized model \hat{theta}^{k-1}
    radius: Array   # R^{k-1}   (scalar, or (num_blocks,) in block mode)
    bits: Array     # b^{k-1}   (scalar int32)


def init_state(theta: Any, cfg: QuantizerConfig) -> QuantState:
    """Quantizer state at k=0: theta_hat = 0 (paper initializes theta^0 = 0)."""
    zeros = jax.tree.map(jnp.zeros_like, theta)
    radius = jnp.zeros((), jnp.float32)
    return QuantState(theta_hat=zeros, radius=radius, bits=jnp.asarray(cfg.bits, jnp.int32))


def _next_bits(cfg: QuantizerConfig, bits_prev: Array, r_new: Array,
               r_prev: Array, base_bits: Array | None = None) -> Array:
    """Bit-growth rule (eq. 11): smallest b s.t. Delta^k <= Delta^{k-1}.

    Elementwise over broadcast-compatible (bits_prev, r_new, r_prev) — the
    layerwise trainer passes (W, L) arrays to run the rule per leaf.
    `base_bits` overrides cfg.bits as the r_prev == 0 fallback (per-leaf
    configured widths); None keeps the global configured bits.
    """
    base = (jnp.asarray(cfg.bits, jnp.int32) if base_bits is None
            else jnp.asarray(base_bits, jnp.int32))
    if not cfg.adapt_bits:
        return jnp.broadcast_to(base, jnp.broadcast_shapes(
            base.shape, jnp.shape(r_new)))
    levels_prev = (2.0 ** bits_prev.astype(jnp.float32)) - 1.0
    ratio = jnp.where(r_prev > 0, r_new / jnp.maximum(r_prev, 1e-30), 0.0)
    needed = jnp.ceil(jnp.log2(1.0 + levels_prev * ratio))
    b = jnp.clip(needed.astype(jnp.int32), 1, cfg.max_bits)
    # first iteration (r_prev == 0): fall back to configured bits
    return jnp.where(r_prev > 0, b, base)


def quantize_tensor(
    theta: Array,
    theta_hat_prev: Array,
    key: Array,
    *,
    radius: Array,
    bits: Array,
) -> tuple[Array, Array]:
    """Quantize one tensor given a (scalar) radius and bit width.

    Returns (q_levels uint8, theta_hat_new).  Levels fit in [0, 2^b - 1] <= 255.
    theta_hat_new is returned in theta_hat_prev's dtype — the same rule
    dequantize_tensor applies on the receiver — so sender and receiver stay
    bit-identical even for mixed-precision pytrees (theta in bf16, hat state
    in f32).  The fused Pallas kernel (repro.kernels.quantize) follows the
    same contract.
    """
    delta_theta = theta.astype(jnp.float32) - theta_hat_prev.astype(jnp.float32)
    levels = (2.0 ** bits.astype(jnp.float32)) - 1.0
    # Guard R == 0 (already converged / first step with theta == theta_hat):
    # then q is all-zero and theta_hat is unchanged.
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    c = (delta_theta + radius) / step
    low = jnp.floor(c)
    p = c - low  # eq. (10)
    u = jax.random.uniform(key, theta.shape, jnp.float32)
    q = low + (u < p).astype(jnp.float32)
    q = jnp.clip(q, 0.0, levels)
    q = jnp.where(radius > 0, q, jnp.zeros_like(q))
    theta_hat = theta_hat_prev.astype(jnp.float32) + step * q - radius
    theta_hat = jnp.where(radius > 0, theta_hat, theta_hat_prev.astype(jnp.float32))
    return q.astype(jnp.uint8), theta_hat.astype(theta_hat_prev.dtype)


def dequantize_tensor(
    q: Array,
    theta_hat_prev: Array,
    *,
    radius: Array,
    bits: Array,
) -> Array:
    """Reconstruction (eq. 13) on the receiver side."""
    levels = (2.0 ** bits.astype(jnp.float32)) - 1.0
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    out = theta_hat_prev.astype(jnp.float32) + step * q.astype(jnp.float32) - radius
    return jnp.where(radius > 0, out, theta_hat_prev.astype(jnp.float32)).astype(
        theta_hat_prev.dtype
    )


def global_radius(theta: Any, theta_hat_prev: Any) -> Array:
    """R^k = || theta - theta_hat_prev ||_inf over the whole pytree."""
    leaves = jax.tree.leaves(
        jax.tree.map(
            lambda a, b: jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
            if a.size
            else jnp.zeros((), jnp.float32),
            theta,
            theta_hat_prev,
        )
    )
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros((), jnp.float32)


def quantize(
    theta: Any,
    state: QuantState,
    key: Array,
    cfg: QuantizerConfig,
) -> tuple[dict[str, Any], QuantState]:
    """Quantize a pytree of tensors with one shared radius (paper-faithful).

    Returns (payload, new_state).  payload = {'q': pytree uint8, 'radius': f32,
    'bits': i32}; its wire size is payload_bits(cfg, d) bits.
    The *sender-side* new_state.theta_hat equals the receiver's reconstruction,
    keeping both sides exactly in sync (key requirement of the algorithm).
    """
    r_new = global_radius(theta, state.theta_hat)
    bits = _next_bits(cfg, state.bits, r_new, state.radius)
    leaves, treedef = jax.tree.flatten(theta)
    hat_leaves = treedef.flatten_up_to(state.theta_hat)
    keys = jax.random.split(key, max(len(leaves), 1))
    qs, hats = [], []
    for x, h, k in zip(leaves, hat_leaves, keys):
        q, hat = quantize_tensor(x, h, k, radius=r_new, bits=bits)
        qs.append(q)
        hats.append(hat)
    payload = {
        "q": jax.tree.unflatten(treedef, qs),
        "radius": r_new,
        "bits": bits,
    }
    new_state = QuantState(
        theta_hat=jax.tree.unflatten(treedef, hats), radius=r_new, bits=bits
    )
    return payload, new_state


def dequantize(payload: dict[str, Any], theta_hat_prev: Any) -> Any:
    """Receiver-side reconstruction of the sender's theta_hat^k."""
    return jax.tree.map(
        lambda q, h: dequantize_tensor(
            q, h, radius=payload["radius"], bits=payload["bits"]
        ),
        payload["q"],
        theta_hat_prev,
    )


def header_bits(adapt_bits: bool = True, num_radii: int = 1) -> int:
    """Per-transmission header: one f32 radius per radius scalar (1 in
    global mode, one per tensor in the dist trainer's per_tensor mode)
    plus the i32 bit width.

    The payload dict always carries `bits` — the protocol transmits it
    every round whether or not the bit-growth rule is active — so it is
    always billed.  (Core used to elide those 32 bits when adapt_bits was
    off, diverging from dist.qgadmm.wire_bits_per_round by one word per
    transmission; `adapt_bits` is kept for call-site compatibility but no
    longer changes the result.)

    Single source of truth for payload accounting — payload_bits,
    gadmm.bits_per_round, the dist trainer's metrics, and the sim's
    per-message billing all use it.
    """
    del adapt_bits
    return 32 * int(num_radii) + 32


def payload_bits(cfg_or_bits, num_params: int, *, adapt_bits: bool = False,
                 num_radii: int = 1) -> int:
    """Wire size in bits of one transmission: b*d + header."""
    if isinstance(cfg_or_bits, QuantizerConfig):
        b = cfg_or_bits.bits
        adapt_bits = cfg_or_bits.adapt_bits
    else:
        b = int(cfg_or_bits)
    return b * num_params + header_bits(adapt_bits, num_radii)
