"""Stochastic quantizer of Q-GADMM (paper eqs. 6-13).

Worker n at iteration k quantizes the *difference* between its current model
theta_n^k and its previously-quantized model theta_hat_n^{k-1}:

    R      = ||theta - theta_hat_prev||_inf                 (quantization radius)
    Delta  = 2 R / (2^b - 1)                                (step size)
    c_i    = (theta_i - theta_hat_prev_i + R) / Delta       (non-negative coords)
    q_i    = ceil(c_i)  w.p.  c_i - floor(c_i)              (stochastic rounding,
             floor(c_i) otherwise                            eq. 7 + eq. 10)
    theta_hat = theta_hat_prev + Delta * q - R * 1          (reconstruction, eq. 13)

The rounding probability choice makes E[theta_hat] = theta (unbiased, eq. 8)
with per-coordinate variance <= Delta^2 / 4.

The payload actually transmitted is (q:int levels, R:f32[, b:int]) ->
b*d + 32 (+ 32 when bits adapt) bits instead of 32*d bits for a
full-precision vector; see header_bits / payload_bits (the same accounting
rule backs gadmm.bits_per_round and the distributed trainer's metrics).

Everything here is pure JAX and jit/vmap/pjit friendly.  A fused Pallas TPU
kernel for the same computation lives in repro/kernels/quantize (ops.q_dequantize
dispatches to it when enabled).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """Static configuration of the stochastic quantizer.

    bits:       quantizer resolution b (levels = 2^b - 1 intervals).  The paper
                uses b=2 for linear regression and b=8 for the DNN task.
    adapt_bits: if True, apply the bit-growth rule (eq. 11) that keeps
                Delta_n^k non-increasing.  The paper observes R_n^k decreases in
                practice so fixed bits suffice; both modes are supported.
    max_bits:   cap for adaptive bits (payload dtype is int8 / packed int4).

    (Tighter-than-global ranges are provided by the distributed trainer's
    radius_mode='per_tensor'; see repro.dist.qgadmm.)
    """

    bits: int = 2
    adapt_bits: bool = False
    max_bits: int = 8

    def __post_init__(self):
        assert 1 <= self.bits <= self.max_bits <= 8


@dataclasses.dataclass
class QuantState:
    """Carried across iterations for one worker's tensor (pytree)."""

    theta_hat: Any  # previously quantized model \hat{theta}^{k-1}
    radius: Array   # R^{k-1}   (scalar, or (num_blocks,) in block mode)
    bits: Array     # b^{k-1}   (scalar int32)


def init_state(theta: Any, cfg: QuantizerConfig) -> QuantState:
    """Quantizer state at k=0: theta_hat = 0 (paper initializes theta^0 = 0)."""
    zeros = jax.tree.map(jnp.zeros_like, theta)
    radius = jnp.zeros((), jnp.float32)
    return QuantState(theta_hat=zeros, radius=radius, bits=jnp.asarray(cfg.bits, jnp.int32))


def _next_bits(cfg: QuantizerConfig, bits_prev: Array, r_new: Array, r_prev: Array) -> Array:
    """Bit-growth rule (eq. 11): smallest b s.t. Delta^k <= Delta^{k-1}."""
    if not cfg.adapt_bits:
        return jnp.asarray(cfg.bits, jnp.int32)
    levels_prev = (2.0 ** bits_prev.astype(jnp.float32)) - 1.0
    ratio = jnp.where(r_prev > 0, r_new / jnp.maximum(r_prev, 1e-30), 0.0)
    needed = jnp.ceil(jnp.log2(1.0 + levels_prev * ratio))
    b = jnp.clip(needed.astype(jnp.int32), 1, cfg.max_bits)
    # first iteration (r_prev == 0): fall back to configured bits
    return jnp.where(r_prev > 0, b, jnp.asarray(cfg.bits, jnp.int32))


def quantize_tensor(
    theta: Array,
    theta_hat_prev: Array,
    key: Array,
    *,
    radius: Array,
    bits: Array,
) -> tuple[Array, Array]:
    """Quantize one tensor given a (scalar) radius and bit width.

    Returns (q_levels uint8, theta_hat_new).  Levels fit in [0, 2^b - 1] <= 255.
    theta_hat_new is returned in theta_hat_prev's dtype — the same rule
    dequantize_tensor applies on the receiver — so sender and receiver stay
    bit-identical even for mixed-precision pytrees (theta in bf16, hat state
    in f32).  The fused Pallas kernel (repro.kernels.quantize) follows the
    same contract.
    """
    delta_theta = theta.astype(jnp.float32) - theta_hat_prev.astype(jnp.float32)
    levels = (2.0 ** bits.astype(jnp.float32)) - 1.0
    # Guard R == 0 (already converged / first step with theta == theta_hat):
    # then q is all-zero and theta_hat is unchanged.
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    c = (delta_theta + radius) / step
    low = jnp.floor(c)
    p = c - low  # eq. (10)
    u = jax.random.uniform(key, theta.shape, jnp.float32)
    q = low + (u < p).astype(jnp.float32)
    q = jnp.clip(q, 0.0, levels)
    q = jnp.where(radius > 0, q, jnp.zeros_like(q))
    theta_hat = theta_hat_prev.astype(jnp.float32) + step * q - radius
    theta_hat = jnp.where(radius > 0, theta_hat, theta_hat_prev.astype(jnp.float32))
    return q.astype(jnp.uint8), theta_hat.astype(theta_hat_prev.dtype)


def dequantize_tensor(
    q: Array,
    theta_hat_prev: Array,
    *,
    radius: Array,
    bits: Array,
) -> Array:
    """Reconstruction (eq. 13) on the receiver side."""
    levels = (2.0 ** bits.astype(jnp.float32)) - 1.0
    safe_r = jnp.maximum(radius, 1e-30)
    step = 2.0 * safe_r / levels
    out = theta_hat_prev.astype(jnp.float32) + step * q.astype(jnp.float32) - radius
    return jnp.where(radius > 0, out, theta_hat_prev.astype(jnp.float32)).astype(
        theta_hat_prev.dtype
    )


def global_radius(theta: Any, theta_hat_prev: Any) -> Array:
    """R^k = || theta - theta_hat_prev ||_inf over the whole pytree."""
    leaves = jax.tree.leaves(
        jax.tree.map(
            lambda a, b: jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
            if a.size
            else jnp.zeros((), jnp.float32),
            theta,
            theta_hat_prev,
        )
    )
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros((), jnp.float32)


def quantize(
    theta: Any,
    state: QuantState,
    key: Array,
    cfg: QuantizerConfig,
) -> tuple[dict[str, Any], QuantState]:
    """Quantize a pytree of tensors with one shared radius (paper-faithful).

    Returns (payload, new_state).  payload = {'q': pytree uint8, 'radius': f32,
    'bits': i32}; its wire size is payload_bits(cfg, d) bits.
    The *sender-side* new_state.theta_hat equals the receiver's reconstruction,
    keeping both sides exactly in sync (key requirement of the algorithm).
    """
    r_new = global_radius(theta, state.theta_hat)
    bits = _next_bits(cfg, state.bits, r_new, state.radius)
    leaves, treedef = jax.tree.flatten(theta)
    hat_leaves = treedef.flatten_up_to(state.theta_hat)
    keys = jax.random.split(key, max(len(leaves), 1))
    qs, hats = [], []
    for x, h, k in zip(leaves, hat_leaves, keys):
        q, hat = quantize_tensor(x, h, k, radius=r_new, bits=bits)
        qs.append(q)
        hats.append(hat)
    payload = {
        "q": jax.tree.unflatten(treedef, qs),
        "radius": r_new,
        "bits": bits,
    }
    new_state = QuantState(
        theta_hat=jax.tree.unflatten(treedef, hats), radius=r_new, bits=bits
    )
    return payload, new_state


def dequantize(payload: dict[str, Any], theta_hat_prev: Any) -> Any:
    """Receiver-side reconstruction of the sender's theta_hat^k."""
    return jax.tree.map(
        lambda q, h: dequantize_tensor(
            q, h, radius=payload["radius"], bits=payload["bits"]
        ),
        payload["q"],
        theta_hat_prev,
    )


def header_bits(adapt_bits: bool) -> int:
    """Per-transmission header: R (f32) always, b (i32) only when the
    bit-growth rule is active (fixed bits need not be retransmitted).

    Single source of truth for payload accounting — payload_bits,
    gadmm.bits_per_round, and the dist trainer's metrics all use it.
    """
    return 32 + 32 * int(bool(adapt_bits))


def payload_bits(cfg_or_bits, num_params: int, *, adapt_bits: bool = False) -> int:
    """Wire size in bits of one transmission: b*d + header."""
    if isinstance(cfg_or_bits, QuantizerConfig):
        b = cfg_or_bits.bits
        adapt_bits = cfg_or_bits.adapt_bits
    else:
        b = int(cfg_or_bits)
    return b * num_params + header_bits(adapt_bits)
