"""Worker topologies: placement, neighbor graphs, and head/tail 2-colorings.

The source paper (Sec. V-A) fixes a chain: workers dropped uniformly at
random in a 250x250 m^2 grid, connected by the nearest-neighbor heuristic of
[23], PS baselines using the min-sum-distance worker as server.  Its
successor CQ-GGADMM (Ben Issaid et al., 2020) generalizes the bipartite
head/tail split to arbitrary graphs: any connected bipartite graph admits
the two-phase Gauss-Seidel sweep, with one dual variable per edge.

This module provides that generalization:

  * ``Topology`` — a connected bipartite neighbor graph over worker ids with
    a head/tail 2-coloring (``color``; heads are color 0), canonical
    head->tail ``edges``, a ``neighbors(i)`` API, and a proper edge coloring
    into matchings (``port``/``matchings``).  The edge coloring is what the
    distributed trainer consumes: each color class is a partial matching, so
    one ``jax.lax.ppermute`` per color moves every payload of that class in
    both directions — the permutations are derived from the graph, never
    hard-coded ``+-1`` chain shifts.
  * builders — ``chain_topology`` / ``ring_topology`` / ``star_topology`` /
    ``torus2d_topology`` / ``bipartite_topology`` (arbitrary edge lists,
    validated connected + 2-colorable) / ``cluster_of_stars_topology``
    (two-tier leader-leaf hierarchies: per-cluster stars over a chain or
    super-hub leader backbone — the L-FGADMM federated shape, still a
    connected bipartite graph so coloring and ``edge_index`` apply
    unchanged).
  * ``Placement`` — worker coordinates plus a ``Topology``;
    ``broadcast_dist`` dispatches on the topology (a worker's transmit power
    is set by its FARTHEST neighbor, e.g. the star hub must reach its
    farthest leaf), instead of silently assuming chain ordering.

``random_placement(n, seed, topology=...)`` keeps the paper's grid drop and
grows the topology axis; the legacy chain fields (``chain``,
``chain_hop_dist``, ``ps_index``, ``ps_dist``) are retained for the PS
baselines and the chain benchmarks.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# ----------------------------------------------------------- edge coloring --
def _edge_coloring(n: int, edges: np.ndarray) -> np.ndarray:
    """Proper edge coloring of a bipartite multigraph-free graph.

    Koenig's theorem: a bipartite graph with maximum degree C is C-edge-
    colorable; this is the classic constructive proof.  For each edge (u, v)
    take colors a free at u and b free at v; if they differ, flip the
    maximal alternating a/b path starting at v (it cannot reach u in a
    bipartite graph), freeing a at v.

    Returns ``port``: an (n, C) int array, ``port[i, c]`` = the neighbor
    matched to worker i in color class c, or -1.  Each color class is a
    matching — directly usable as a ppermute permutation.
    """
    if len(edges) == 0:
        return -np.ones((n, 0), np.int64)
    deg = np.bincount(np.asarray(edges).ravel(), minlength=n)
    c_max = int(deg.max())
    port = -np.ones((n, c_max), np.int64)

    def first_free(x: int) -> int:
        for c in range(c_max):
            if port[x, c] < 0:
                return c
        raise AssertionError("edge coloring needs more colors than max degree"
                             " — graph is not simple/bipartite")

    for u, v in np.asarray(edges):
        u, v = int(u), int(v)
        a, b = first_free(u), first_free(v)
        if a != b:
            # walk the alternating a/b path from v and flip its colors
            path = []
            x, c = v, a
            while port[x, c] >= 0:
                y = int(port[x, c])
                path.append((x, y, c))
                x, c = y, (b if c == a else a)
            for x, y, c in path:
                port[x, c] = port[y, c] = -1
            for x, y, c in path:
                o = b if c == a else a
                port[x, o] = y
                port[y, o] = x
        port[u, a] = v
        port[v, a] = u
    return port


def _two_color(n: int, edges: np.ndarray) -> np.ndarray:
    """BFS head/tail 2-coloring; raises if the graph is not bipartite or not
    connected (GADMM needs both: phases alternate colors, consensus needs
    connectivity)."""
    adj: list[list[int]] = [[] for _ in range(n)]
    for u, v in np.asarray(edges):
        adj[int(u)].append(int(v))
        adj[int(v)].append(int(u))
    color = -np.ones(n, np.int8)
    color[0] = 0
    queue = [0]
    while queue:
        x = queue.pop()
        for y in adj[x]:
            if color[y] < 0:
                color[y] = 1 - color[x]
                queue.append(y)
            elif color[y] == color[x]:
                raise ValueError("topology is not bipartite: edge "
                                 f"({x}, {y}) joins two color-{color[x]} "
                                 "workers — no head/tail split exists")
    if n and (color < 0).any():
        raise ValueError("topology is not connected: workers "
                         f"{np.flatnonzero(color < 0).tolist()} are "
                         "unreachable from worker 0")
    return color


@dataclasses.dataclass(frozen=True, eq=False)
class Topology:
    """Connected bipartite worker graph with a head/tail coloring.

    edges: (E, 2) int, canonically oriented head -> tail (edges[:, 0] is the
           head endpoint).  One GADMM dual variable lives on each edge.
    color: (N,) int8 node coloring; heads = 0 transmit in phase one, tails =
           1 in phase two.
    port:  (N, C) int edge coloring, C = max degree: ``port[i, c]`` is i's
           neighbor via the color-c matching (or -1).  Color classes are the
           ppermute rounds of the distributed trainer.
    """

    kind: str
    n: int
    edges: np.ndarray
    color: np.ndarray
    port: np.ndarray

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    @property
    def num_ports(self) -> int:
        return self.port.shape[1]

    @property
    def head_mask(self) -> np.ndarray:
        return self.color == 0

    @property
    def degree(self) -> np.ndarray:
        return (self.port >= 0).sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        """Worker i's neighbors (sorted by edge-color port index)."""
        row = self.port[i]
        return row[row >= 0]

    def matchings(self) -> list[np.ndarray]:
        """Edge color classes, each a (Mc, 2) array of (u, v) with u < v."""
        out = []
        for c in range(self.num_ports):
            pairs = [(i, int(p)) for i, p in enumerate(self.port[:, c])
                     if 0 <= p and i < p]
            out.append(np.asarray(pairs, np.int64).reshape(-1, 2))
        return out

    def adjacency(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), bool)
        if len(self.edges):
            a[self.edges[:, 0], self.edges[:, 1]] = True
            a[self.edges[:, 1], self.edges[:, 0]] = True
        return a

    def edge_lookup(self, i: int) -> dict[int, int]:
        """Worker i's {neighbor id -> undirected edge id} map (the
        derivation GraphActor and the timeline share)."""
        out = {}
        for e, (h, t) in enumerate(self.edges):
            if int(h) == i:
                out[int(t)] = e
            elif int(t) == i:
                out[int(h)] = e
        return out


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeIndex:
    """Directed-edge view of a Topology — the O(E) state layout.

    Every undirected edge (h, t) appears twice, once per direction
    ``src -> dst``; a directed edge d is the slot where worker ``dst[d]``
    stores what it knows about ``src[d]`` (the neighbor-hat reconstruction
    and its mirror of the shared edge dual).  Directed edges are sorted by
    ``(dst, src)``: each worker's incoming slots are contiguous, and a
    ``segment_sum`` over ``dst`` adds a worker's neighbor terms in
    ascending-neighbor order — the same order a dense ``adj @ hat``
    row-reduction uses, which is what keeps the edge-indexed aggregation
    bitwise-identical to the port-dense one on CPU.

    src, dst: (2E,) worker ids (payloads flow src -> dst).
    edge:     (2E,) undirected edge id into ``topo.edges``.
    color:    (2E,) edge color of that edge (Koenig matching index).
    slot:     (N, C) int: ``slot[w, c]`` = the directed edge with dst=w
              whose color is c, or -1 where w has no color-c edge — the
              port-dense <-> edge-indexed projection table.
    sign_dst: (2E,) float32: +1.0 where dst is the head endpoint, -1.0
              where it is the tail (the dual's canonical head -> tail
              orientation, seen from the storing endpoint).
    """

    src: np.ndarray
    dst: np.ndarray
    edge: np.ndarray
    color: np.ndarray
    slot: np.ndarray
    sign_dst: np.ndarray

    @property
    def num_directed(self) -> int:
        return len(self.src)

    def in_edges(self, i: int) -> dict[int, int]:
        """Worker i's {neighbor id -> directed edge with dst=i} map."""
        ds = np.flatnonzero(self.dst == i)
        return {int(self.src[d]): int(d) for d in ds}


def edge_index(topo: Topology) -> EdgeIndex:
    """Build the directed-edge tables for a topology (W=1 / E=0 safe:
    every array is empty and ``slot`` is all -1)."""
    n, c_max = topo.port.shape
    e = topo.edges
    if len(e) == 0:
        z = np.zeros((0,), np.int64)
        return EdgeIndex(src=z, dst=z, edge=z, color=z.copy(),
                         slot=-np.ones((n, c_max), np.int64),
                         sign_dst=np.zeros((0,), np.float32))
    # color of each undirected edge from the port table
    ecolor = np.empty(len(e), np.int64)
    for i, (h, t) in enumerate(e):
        ecolor[i] = int(np.flatnonzero(topo.port[h] == t)[0])
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    eid = np.concatenate([np.arange(len(e))] * 2)
    order = np.lexsort((src, dst))
    src, dst, eid = src[order], dst[order], eid[order]
    color = ecolor[eid]
    slot = -np.ones((n, c_max), np.int64)
    slot[dst, color] = np.arange(len(dst))
    sign_dst = np.where(topo.head_mask[dst], 1.0, -1.0).astype(np.float32)
    return EdgeIndex(src=src, dst=dst, edge=eid, color=color, slot=slot,
                     sign_dst=sign_dst)


def edge_schedule(topo: Topology) -> list[list[tuple[int, int]]]:
    """One ppermute permutation per edge color, derived from the graph.

    Color class c is a matching, so sending BOTH directions of each of its
    edges is still a valid (partial) permutation: every worker appears at
    most once as source and once as destination.  Workers without a
    color-c edge receive ppermute's zero fill.  This is the single
    canonical schedule derivation — the distributed trainer's exchange and
    the simulator consume the same list."""
    perms = []
    for m in topo.matchings():
        perms.append([(int(u), int(v)) for u, v in m]
                     + [(int(v), int(u)) for u, v in m])
    return perms


def _make(kind: str, n: int, raw_edges,
          prefer_head: int | None = None) -> Topology:
    edges = np.asarray(sorted({(min(int(u), int(v)), max(int(u), int(v)))
                               for u, v in raw_edges if int(u) != int(v)}),
                       np.int64).reshape(-1, 2)
    color = _two_color(n, edges)
    if prefer_head is not None and color[prefer_head] == 1:
        color = (1 - color).astype(np.int8)  # global flip: coloring is
        # unique up to swapping heads/tails on a connected bipartite graph
    # canonical head -> tail orientation
    if len(edges):
        flip = color[edges[:, 0]] == 1
        edges = np.where(flip[:, None], edges[:, ::-1], edges)
        edges = edges[np.lexsort((edges[:, 1], edges[:, 0]))]
    port = _edge_coloring(n, edges)
    return Topology(kind=kind, n=n, edges=edges, color=color, port=port)


def chain_topology(n: int) -> Topology:
    """The paper's chain: worker i <-> i+1; heads at even positions."""
    assert n >= 1
    return _make("chain", n, [(i, i + 1) for i in range(n - 1)])


def ring_topology(n: int) -> Topology:
    """Chain closed into a cycle.  n must be even (odd cycles are not
    2-colorable); n == 2 degenerates to the 2-chain."""
    assert n >= 2 and n % 2 == 0, \
        f"ring needs an even worker count (odd cycles are not bipartite), got {n}"
    return _make("ring", n, [(i, (i + 1) % n) for i in range(n)])


def star_topology(n: int, hub: int = 0) -> Topology:
    """PS-like star: every worker connects only to the hub.  The hub is the
    single head (transmits alone in phase one, like a PS downlink); leaves
    are tails."""
    assert n >= 2 and 0 <= hub < n
    edges = [(hub, i) for i in range(n) if i != hub]
    return _make("star", n, edges, prefer_head=hub)


def torus2d_topology(rows: int, cols: int) -> Topology:
    """2D torus (rows x cols grid with wraparound).  Both dims must be even
    for 2-colorability; dim == 2 degenerates gracefully (the wrap edge
    coincides with the direct edge and is deduplicated)."""
    assert rows >= 2 and cols >= 2 and rows % 2 == 0 and cols % 2 == 0, \
        f"2d-torus needs even dims >= 2, got {rows}x{cols}"
    edges = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            edges.append((i, r * cols + (c + 1) % cols))
            edges.append((i, ((r + 1) % rows) * cols + c))
    return _make("torus2d", rows * cols, edges)


def bipartite_topology(n: int, edges) -> Topology:
    """Arbitrary connected bipartite graph from an explicit edge list; the
    head/tail coloring is recovered by BFS (raises if none exists)."""
    return _make("bipartite", n, edges)


def _cluster_bounds(n: int, clusters: int) -> tuple[np.ndarray, np.ndarray]:
    """Split n workers into ``clusters`` contiguous id ranges, sizes as
    equal as possible (first ``n % clusters`` ranges get one extra)."""
    sizes = np.full(clusters, n // clusters, np.int64)
    sizes[: n % clusters] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return starts, sizes


def default_clusters(n: int) -> int:
    """Cluster-count heuristic for the two-tier builders: ~sqrt(n) leaders
    balances backbone depth against per-leader fan-out (n = 10^4 -> 100
    clusters of 100)."""
    return max(1, int(round(np.sqrt(n))))


def cluster_of_stars_topology(n: int, clusters: int | None = None,
                              backbone: str = "chain") -> Topology:
    """Two-tier hierarchical graph: per-cluster stars joined by a leader
    backbone (the L-FGADMM federated leader-leaf composition).

    Workers are split into ``clusters`` contiguous id ranges; the first id
    of each range is the cluster leader and its remaining ids are leaves
    (a star).  Leaders are then joined by a ``backbone``:

      * ``'chain'`` — leaders form a chain (kind ``cluster_of_stars``);
      * ``'star'``  — leaders all connect to leader 0, the super-hub
        (kind ``federated`` — the PS-like two-tier shape).

    Both compositions are trees of stars, hence connected and bipartite,
    so the existing BFS 2-coloring, Koenig edge coloring, and
    ``edge_index`` apply unchanged.  ``clusters=None`` picks
    ``default_clusters(n)`` (~sqrt(n)).
    """
    assert n >= 2
    c = default_clusters(n) if clusters is None else int(clusters)
    assert 1 <= c <= n, f"need 1 <= clusters <= n, got {c} for n={n}"
    assert backbone in ("chain", "star"), backbone
    starts, sizes = _cluster_bounds(n, c)
    edges: list[tuple[int, int]] = []
    for s, sz in zip(starts.tolist(), sizes.tolist()):
        edges.extend((s, s + j) for j in range(1, sz))
    if backbone == "chain":
        kind = "cluster_of_stars"
        edges.extend((int(starts[j]), int(starts[j + 1]))
                     for j in range(c - 1))
    else:
        kind = "federated"
        edges.extend((int(starts[0]), int(starts[j])) for j in range(1, c))
    return _make(kind, n, edges, prefer_head=0)


def _torus_dims(n: int) -> tuple[int, int]:
    """Most-square even x even factorization of n (requires n % 4 == 0)."""
    assert n % 4 == 0, f"2d-torus needs num_workers % 4 == 0, got {n}"
    best = (2, n // 2)
    r = 2
    while r * r <= n:
        if n % r == 0 and r % 2 == 0 and (n // r) % 2 == 0:
            best = (r, n // r)
        r += 2
    return best


TOPOLOGY_KINDS = ("chain", "ring", "star", "torus2d",
                  "cluster_of_stars", "federated")


def build_topology(kind_or_topo, n: int) -> Topology:
    """Resolve a topology spec (a kind name or an explicit Topology) for n
    workers — the single entry point used by DistConfig consumers."""
    if isinstance(kind_or_topo, Topology):
        assert kind_or_topo.n == n, (kind_or_topo.n, n)
        return kind_or_topo
    kind = str(kind_or_topo)
    if kind == "chain":
        return chain_topology(n)
    if kind == "ring":
        return ring_topology(n)
    if kind == "star":
        return star_topology(n)
    if kind == "torus2d":
        return torus2d_topology(*_torus_dims(n))
    if kind == "cluster_of_stars":
        return cluster_of_stars_topology(n)
    if kind == "federated":
        return cluster_of_stars_topology(n, backbone="star")
    raise ValueError(f"unknown topology {kind!r}; expected one of "
                     f"{TOPOLOGY_KINDS} or a Topology instance")


# --------------------------------------------------------------- placement --
# Above this worker count the placement helpers switch from the paper's
# O(N^2) heuristics (nearest-neighbor chain walk, full pairwise matrix) to
# O(N) equivalents; small-N results are bit-identical to the pre-gate code.
DENSE_PLACEMENT_MAX = 1024


@dataclasses.dataclass(frozen=True, eq=False)
class Placement:
    positions: np.ndarray       # (N, 2) worker coordinates in meters
    chain: np.ndarray           # (N,) permutation: chain order of worker ids
    ps_index: int               # worker id acting as parameter server
    chain_hop_dist: np.ndarray  # (N-1,) distance between chain neighbors
    ps_dist: np.ndarray         # (N,) distance of every worker to the PS
    topology: Topology | None = None  # None = legacy chain placement

    @property
    def n(self) -> int:
        return len(self.positions)

    def resolved_topology(self) -> Topology:
        if self.topology is not None:
            return self.topology
        # legacy chain placements: graph over worker ids from the chain order
        order = self.chain
        return _make("chain", self.n,
                     [(int(order[j]), int(order[j + 1]))
                      for j in range(self.n - 1)],
                     prefer_head=int(order[0]) if self.n else None)

    def edge_dists(self) -> np.ndarray:
        """(E,) meters per undirected topology edge, in ``topo.edges``
        order — the only pairwise distances the network model ever needs
        (O(E), never the O(N^2) full matrix)."""
        topo = self.resolved_topology()
        e = topo.edges
        if not len(e):
            return np.zeros((0,))
        return np.linalg.norm(self.positions[e[:, 0]] - self.positions[e[:, 1]],
                              axis=1)

    def broadcast_dist(self) -> np.ndarray:
        """Per-worker transmit distance: the FARTHEST topology neighbor.

        A worker broadcasts one payload to all its neighbors; its transmit
        power is set by the farthest one.  Dispatches on the placement's
        topology (the old implementation silently assumed chain ordering):
        on a star the hub must reach its farthest leaf (PS-downlink-like),
        on a ring/torus each worker looks at its cycle/grid neighbors.
        Returned in worker-id order (index i = worker i).  Vectorized as a
        segment max over the per-edge distances (O(E)).
        """
        topo = self.resolved_topology()
        out = np.zeros(self.n)
        if topo.num_edges:
            d = self.edge_dists()
            np.maximum.at(out, topo.edges[:, 0], d)
            np.maximum.at(out, topo.edges[:, 1], d)
        return out


def random_placement(n: int, seed: int, grid: float = 250.0,
                     topology: str = "chain") -> Placement:
    """Drop n workers uniformly in the grid and connect them.

    topology='chain' reproduces the paper: nearest-neighbor chain heuristic
    of [23].  'ring' closes that chain into a cycle (even n), 'star' uses
    the min-sum-distance worker as hub (the PS-baseline server choice), and
    'torus2d' lays the chain order onto the most-square even torus grid.
    """
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, grid, size=(n, 2))
    if n > DENSE_PLACEMENT_MAX:
        # Large-N path, O(N): the nearest-neighbor chain walk and the full
        # pairwise matrix are both O(N^2) and unusable at 10^4+ workers.
        # Chain = id order; PS = the worker nearest the centroid (the
        # min-sum-distance worker converges to it for uniform drops).
        chain = np.arange(n)
        ps = int(np.argmin(np.linalg.norm(pos - pos.mean(axis=0), axis=1)))
        ps_dist = np.linalg.norm(pos - pos[ps], axis=1)
    else:
        # nearest-neighbor chain heuristic
        start = int(np.argmin(pos.sum(axis=1)))
        unvisited = set(range(n)) - {start}
        chain = [start]
        while unvisited:
            last = pos[chain[-1]]
            nxt = min(unvisited,
                      key=lambda j: float(np.sum((pos[j] - last) ** 2)))
            chain.append(nxt)
            unvisited.remove(nxt)
        chain = np.asarray(chain)
        # PS = min sum distance to all others
        dmat = np.linalg.norm(pos[None, :, :] - pos[:, None, :], axis=-1)
        ps = int(np.argmin(dmat.sum(axis=1)))
        ps_dist = dmat[ps]
    hop = np.linalg.norm(pos[chain[1:]] - pos[chain[:-1]], axis=1)

    if topology == "chain":
        topo = _make("chain", n, [(int(chain[j]), int(chain[j + 1]))
                                  for j in range(n - 1)],
                     prefer_head=int(chain[0]))
    elif topology == "ring":
        assert n >= 2 and n % 2 == 0, \
            f"ring needs an even worker count (odd cycles are not " \
            f"bipartite), got {n}"
        topo = _make("ring", n, [(int(chain[j]), int(chain[(j + 1) % n]))
                                 for j in range(n)],
                     prefer_head=int(chain[0]))
    elif topology == "star":
        topo = star_topology(n, hub=ps)
    elif topology == "torus2d":
        rows, cols = _torus_dims(n)
        grid_ids = chain.reshape(rows, cols)
        edges = []
        for r in range(rows):
            for c in range(cols):
                edges.append((int(grid_ids[r, c]),
                              int(grid_ids[r, (c + 1) % cols])))
                edges.append((int(grid_ids[r, c]),
                              int(grid_ids[(r + 1) % rows, c])))
        topo = _make("torus2d", n, edges)
    elif topology in ("cluster_of_stars", "federated"):
        topo = cluster_of_stars_topology(
            n, backbone="chain" if topology == "cluster_of_stars" else "star")
    else:
        raise ValueError(f"unknown topology {topology!r}")

    return Placement(
        positions=pos,
        chain=chain,
        ps_index=ps,
        chain_hop_dist=hop,
        ps_dist=ps_dist,
        topology=topo,
    )


def head_tail_split(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Chain positions 0,2,4,... are heads; 1,3,5,... are tails (paper's
    1-indexed odd/even)."""
    idx = np.arange(n)
    return idx[idx % 2 == 0], idx[idx % 2 == 1]
