"""Chain topology construction (paper Sec. V-A settings).

Workers are dropped uniformly at random in a 250x250 m^2 grid.  The
decentralized algorithms (GADMM / Q-GADMM) connect them in a chain built by the
nearest-neighbor heuristic of [23]: start from an arbitrary worker (we use the
one closest to the grid corner) and repeatedly append the nearest unvisited
worker.  PS-based baselines use the worker with minimum sum-distance to all
others as the parameter server.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Placement:
    positions: np.ndarray      # (N, 2) worker coordinates in meters
    chain: np.ndarray          # (N,) permutation: chain order of worker ids
    ps_index: int              # worker id acting as parameter server
    chain_hop_dist: np.ndarray  # (N-1,) distance between chain neighbors
    ps_dist: np.ndarray        # (N,) distance of every worker to the PS

    @property
    def n(self) -> int:
        return len(self.positions)

    def broadcast_dist(self) -> np.ndarray:
        """Per-worker transmit distance on the chain: the farther neighbor.

        Worker i (chain position) broadcasts its model to both neighbors; the
        transmit power is set by the farther of the two.
        """
        d = self.chain_hop_dist
        out = np.empty(self.n)
        out[0] = d[0]
        out[-1] = d[-1]
        if self.n > 2:
            out[1:-1] = np.maximum(d[:-1], d[1:])
        return out


def random_placement(n: int, seed: int, grid: float = 250.0) -> Placement:
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, grid, size=(n, 2))
    # nearest-neighbor chain heuristic
    start = int(np.argmin(pos.sum(axis=1)))
    unvisited = set(range(n)) - {start}
    chain = [start]
    while unvisited:
        last = pos[chain[-1]]
        nxt = min(unvisited, key=lambda j: float(np.sum((pos[j] - last) ** 2)))
        chain.append(nxt)
        unvisited.remove(nxt)
    chain = np.asarray(chain)
    hop = np.linalg.norm(pos[chain[1:]] - pos[chain[:-1]], axis=1)
    # PS = min sum distance to all others
    dmat = np.linalg.norm(pos[None, :, :] - pos[:, None, :], axis=-1)
    ps = int(np.argmin(dmat.sum(axis=1)))
    return Placement(
        positions=pos,
        chain=chain,
        ps_index=ps,
        chain_hop_dist=hop,
        ps_dist=dmat[ps],
    )


def head_tail_split(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Chain positions 0,2,4,... are heads; 1,3,5,... are tails (paper's
    1-indexed odd/even)."""
    idx = np.arange(n)
    return idx[idx % 2 == 0], idx[idx % 2 == 1]
