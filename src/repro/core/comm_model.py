"""Wireless communication cost model of paper Sec. V-A.

Free-space pathloss, Shannon capacity: to ship `bits` within slot time tau over
bandwidth B at distance D with noise PSD N0, the required rate is
R = bits / tau [bit/s], the required transmit power is

    P = D^2 * N0 * B * (2^(R/B) - 1)        (Shannon, free-space D^2 loss)

and the consumed energy is E = P * tau.  Paper defaults: total system bandwidth
2 MHz split across concurrently-transmitting workers; N0 = 1e-6 W/Hz; tau = 1 ms
(100 ms for the DNN task).

Bandwidth split: GADMM-family alternates head/tail groups so only half the
workers transmit per communication round -> each gets (2*Btot/N); PS-based
algorithms have all N workers competing -> Btot/N.

Beyond the paper's chain, ``round_energy_topology`` prices a round on any
bipartite topology (core.topology) — per-phase bandwidth sharing within the
transmitting head/tail group, per-worker broadcast distance from the
topology-dispatched ``Placement.broadcast_dist`` — and supports CQ-GGADMM
censoring: skipped workers transmit only the 1-bit censor flag.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RadioConfig:
    total_bandwidth_hz: float = 2e6
    noise_psd: float = 1e-6         # W/Hz
    slot_s: float = 1e-3            # tau
    n_workers: int = 50

    def worker_bandwidth(self, decentralized: bool) -> float:
        share = 2.0 if decentralized else 1.0
        return share * self.total_bandwidth_hz / self.n_workers


def tx_energy(bits: float, dist_m: float, bandwidth_hz: float,
              slot_s: float, noise_psd: float) -> float:
    """Energy (J) to transmit `bits` in one slot at distance dist_m."""
    rate = bits / slot_s
    power = (dist_m**2) * noise_psd * bandwidth_hz * (2.0 ** (rate / bandwidth_hz) - 1.0)
    return power * slot_s


def round_energy_decentralized(bits_per_worker: np.ndarray, dists: np.ndarray,
                               radio: RadioConfig) -> float:
    """Sum energy of one GADMM/Q-GADMM communication round (all N broadcasts)."""
    bw = radio.worker_bandwidth(decentralized=True)
    return float(
        sum(tx_energy(b, d, bw, radio.slot_s, radio.noise_psd)
            for b, d in zip(np.broadcast_to(bits_per_worker, dists.shape), dists))
    )


def round_energy_ps(upload_bits: float, ps_dists: np.ndarray,
                    download_bits: float, radio: RadioConfig) -> float:
    """N uplinks of upload_bits + one PS downlink of download_bits (to the
    farthest worker, full band)."""
    bw = radio.worker_bandwidth(decentralized=False)
    up = sum(tx_energy(upload_bits, d, bw, radio.slot_s, radio.noise_psd)
             for d in ps_dists)
    down = tx_energy(download_bits, float(ps_dists.max()),
                     radio.total_bandwidth_hz, radio.slot_s, radio.noise_psd)
    return float(up + down)


def round_energy_topology(placement, bits_per_worker, radio: RadioConfig,
                          sent=None, flag_bits: int | None = None) -> float:
    """Energy of one GGADMM round on an arbitrary bipartite topology,
    optionally with censored transmissions (CQ-GGADMM).

    The round has two phases — heads broadcast, then tails broadcast — and
    only the transmitting group shares the band, so each transmitter in a
    group of size G gets total_bandwidth / G (the chain's 50/50 head/tail
    split reduces to the paper's 2*Btot/N rule).  Every worker broadcasts
    once per round at the power its FARTHEST neighbor requires
    (placement.broadcast_dist, topology-dispatched: the star hub must reach
    its farthest leaf).

    With censoring, ``sent`` is an (N,) bool mask of the workers that
    cleared the threshold this round; the others transmit only the
    ``flag_bits`` censor flag (default core.censor.FLAG_BITS).
    """
    topo = placement.resolved_topology()
    bd = placement.broadcast_dist()
    bits = np.broadcast_to(np.asarray(bits_per_worker, float), (topo.n,))
    if sent is not None:
        if flag_bits is None:
            from .censor import FLAG_BITS as flag_bits
        bits = np.where(np.asarray(sent, bool), bits, float(flag_bits))
    heads = np.flatnonzero(topo.head_mask)
    tails = np.flatnonzero(~topo.head_mask)
    total = 0.0
    for group in (heads, tails):
        if not len(group):
            continue
        bw = radio.total_bandwidth_hz / len(group)
        total += sum(tx_energy(bits[i], bd[i], bw, radio.slot_s,
                               radio.noise_psd) for i in group)
    return float(total)
