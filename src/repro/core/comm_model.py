"""Wireless communication cost model of paper Sec. V-A.

Free-space pathloss, Shannon capacity: to ship `bits` within slot time tau over
bandwidth B at distance D with noise PSD N0, the required rate is
R = bits / tau [bit/s], the required transmit power is

    P = D^2 * N0 * B * (2^(R/B) - 1)        (Shannon, free-space D^2 loss)

and the consumed energy is E = P * tau.  Paper defaults: total system bandwidth
2 MHz split across concurrently-transmitting workers; N0 = 1e-6 W/Hz; tau = 1 ms
(100 ms for the DNN task).

Bandwidth split: GADMM-family alternates head/tail groups so only half the
workers transmit per communication round -> each gets (2*Btot/N); PS-based
algorithms have all N workers competing -> Btot/N.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RadioConfig:
    total_bandwidth_hz: float = 2e6
    noise_psd: float = 1e-6         # W/Hz
    slot_s: float = 1e-3            # tau
    n_workers: int = 50

    def worker_bandwidth(self, decentralized: bool) -> float:
        share = 2.0 if decentralized else 1.0
        return share * self.total_bandwidth_hz / self.n_workers


def tx_energy(bits: float, dist_m: float, bandwidth_hz: float,
              slot_s: float, noise_psd: float) -> float:
    """Energy (J) to transmit `bits` in one slot at distance dist_m."""
    rate = bits / slot_s
    power = (dist_m**2) * noise_psd * bandwidth_hz * (2.0 ** (rate / bandwidth_hz) - 1.0)
    return power * slot_s


def round_energy_decentralized(bits_per_worker: np.ndarray, dists: np.ndarray,
                               radio: RadioConfig) -> float:
    """Sum energy of one GADMM/Q-GADMM communication round (all N broadcasts)."""
    bw = radio.worker_bandwidth(decentralized=True)
    return float(
        sum(tx_energy(b, d, bw, radio.slot_s, radio.noise_psd)
            for b, d in zip(np.broadcast_to(bits_per_worker, dists.shape), dists))
    )


def round_energy_ps(upload_bits: float, ps_dists: np.ndarray,
                    download_bits: float, radio: RadioConfig) -> float:
    """N uplinks of upload_bits + one PS downlink of download_bits (to the
    farthest worker, full band)."""
    bw = radio.worker_bandwidth(decentralized=False)
    up = sum(tx_energy(upload_bits, d, bw, radio.slot_s, radio.noise_psd)
             for d in ps_dists)
    down = tx_energy(download_bits, float(ps_dists.max()),
                     radio.total_bandwidth_hz, radio.slot_s, radio.noise_psd)
    return float(up + down)
