"""Parameter-server baselines of the paper's evaluation: GD, QGD, ADIANA.

All solve  min_theta sum_n f_n(theta),  f_n quadratic (linear regression),
with N workers uploading (possibly quantized) gradients to a PS each round and
the PS broadcasting the model back.

Communication accounting per iteration (paper Sec. V-A):
  GD:     N uploads of 32 d bits            + PS download 32 d
  QGD:    N uploads of (b d + 32) bits      + PS download 32 d
  ADIANA: N uploads of 2 quantized vectors (32 + 2 b d) + PS download 32 d
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PSProblem:
    xtx: Array   # (N, d, d)
    xty: Array   # (N, d)

    @property
    def n(self) -> int:
        return self.xtx.shape[0]

    @property
    def d(self) -> int:
        return self.xtx.shape[-1]

    def grad(self, theta: Array) -> Array:
        """Per-worker gradients, (N, d)."""
        return jnp.einsum("nde,e->nd", self.xtx, theta) - self.xty

    def objective(self, theta: Array) -> Array:
        quad = 0.5 * jnp.einsum("d,nde,e->", theta, self.xtx, theta)
        return quad - jnp.einsum("nd,d->", self.xty, theta)

    def lipschitz(self) -> float:
        total = jnp.sum(self.xtx, axis=0)
        return float(jnp.linalg.eigvalsh(total)[-1])

    def strong_convexity(self) -> float:
        total = jnp.sum(self.xtx, axis=0)
        return float(jnp.linalg.eigvalsh(total)[0])


def _stoch_quantize(g: Array, key: Array, bits: int) -> Array:
    """Unbiased stochastic quantization of a raw vector (range = inf norm)."""
    r = jnp.max(jnp.abs(g))
    levels = 2.0**bits - 1.0
    safe_r = jnp.maximum(r, 1e-30)
    step = 2.0 * safe_r / levels
    c = (g + r) / step
    low = jnp.floor(c)
    u = jax.random.uniform(key, g.shape)
    q = jnp.clip(low + (u < (c - low)), 0.0, levels)
    out = step * q - r
    return jnp.where(r > 0, out, g)


def run_gd(problem: PSProblem, iters: int, lr: float | None = None,
           quantize_bits: int | None = None, seed: int = 0):
    """(Q)GD: returns (thetas (iters, d), bits_per_iter)."""
    lr = lr if lr is not None else 1.0 / problem.lipschitz()
    d = problem.d

    def body(carry, k):
        theta, key = carry
        g = problem.grad(theta)
        if quantize_bits is not None:
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, problem.n)
            g = jax.vmap(lambda gi, ki: _stoch_quantize(gi, ki, quantize_bits))(g, keys)
        theta = theta - lr * jnp.sum(g, axis=0)
        return (theta, key), theta

    (_, _), thetas = jax.lax.scan(
        body, (jnp.zeros((d,)), jax.random.PRNGKey(seed)), jnp.arange(iters))
    if quantize_bits is None:
        up = 32 * d
    else:
        up = quantize_bits * d + 32
    bits_per_iter = problem.n * up + 32 * d
    return thetas, bits_per_iter


def run_adiana(problem: PSProblem, iters: int, bits: int = 2, seed: int = 0):
    """Accelerated DIANA [Li et al. 2020], quantized gradient differences.

    Parameters follow the strongly-convex setting of the source paper with the
    random-quantization variance parameter omega ~ min(d/s^2, sqrt(d)/s),
    s = 2^b - 1:  alpha = 1/(1+omega), eta = min(1/(2L(1+...)), ...) simplified
    to eta = 1/(2 L (1 + omega)), theta-momentum tau, and gamma from mu.
    """
    d = problem.d
    n = problem.n
    L = problem.lipschitz()
    mu = max(problem.strong_convexity(), 1e-12)
    s = 2.0**bits - 1.0
    omega = min(d / s**2, jnp.sqrt(d) / s)
    alpha = 1.0 / (1.0 + omega)
    eta = 1.0 / (2.0 * L * (1.0 + omega))
    tau = min(0.5, float(jnp.sqrt(eta * mu)))
    gamma = eta / (2.0 * (tau + eta * mu))

    def body(carry, k):
        y, z, h, key = carry  # h: (N, d) per-worker shifts
        x = tau * z + (1.0 - tau) * y
        g_local = problem.grad(x)  # (N, d) with grad of sum split per worker
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        delta = jax.vmap(lambda gi, hi, ki: _stoch_quantize(gi - hi, ki, bits))(
            g_local, h, keys)
        g = jnp.sum(h + delta, axis=0)
        h = h + alpha * delta
        y_new = x - eta * g
        z_new = (z + gamma * mu * x - gamma * g) / (1.0 + gamma * mu)
        return (y_new, z_new, h, key), y_new

    z0 = jnp.zeros((d,))
    (_, _, _, _), ys = jax.lax.scan(
        body, (z0, z0, jnp.zeros((n, d)), jax.random.PRNGKey(seed)),
        jnp.arange(iters))
    bits_per_iter = n * (32 + 2 * bits * d) + 32 * d
    return ys, bits_per_iter
