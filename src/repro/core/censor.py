"""Censored transmissions (CQ-GGADMM, Ben Issaid et al. 2020).

Q-GADMM transmits every worker's quantized delta every round.  Its successor
CQ-GGADMM adds *communication censoring*: worker n transmits its new
quantized model theta_hat_n^{k+1} only when it differs enough from the last
value its neighbors hold,

    || theta_hat_n^{k+1} - theta_hat_n^{last sent} ||_2  >  tau * xi^k ,

with tau > 0 and a decay rate 0 < xi < 1 so the threshold vanishes and
censoring never stalls convergence (their Theorem 1 keeps the GADMM rate for
xi in (theta-linear range)).  A censored round transmits only a 1-bit flag;
the receivers keep using the previous hat, and — because the skip decision
is a function of quantized values the sender itself committed — the sender
rolls its own hat/radius/bits state back too, so both ends of every edge
stay bit-identical (the algorithm's key invariant survives censoring).

This module is the single source of truth for the rule: the core graph
reference (``repro.core.gadmm.graph_step``), the distributed trainer
(``repro.dist.qgadmm`` via ``DistConfig.censor``), and the wire/energy
accounting (``FLAG_BITS``) all import it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

#: Bits a censored (skipped) directed transmission still costs on the wire:
#: the censor flag itself.  Charged per link, direction, and phase by
#: ``QGADMMTrainer.wire_bits_per_round`` and ``comm_model``.
FLAG_BITS = 1


@dataclasses.dataclass(frozen=True)
class CensorConfig:
    """Decaying censoring threshold tau * xi^k.

    tau: initial threshold, in the units of || theta_hat ||_2.  Larger means
         more rounds censored early on.
    xi:  per-round geometric decay in (0, 1); the threshold -> 0 so late
         rounds always transmit and the fixed point is unchanged.
    """

    tau: float = 0.05
    xi: float = 0.9

    def __post_init__(self):
        assert self.tau > 0, f"tau must be positive, got {self.tau}"
        assert 0.0 < self.xi < 1.0, f"xi must be in (0, 1), got {self.xi}"


def threshold(cfg: CensorConfig, step: Array) -> Array:
    """tau * xi^k for (possibly traced) round index k."""
    return cfg.tau * jnp.power(
        jnp.float32(cfg.xi), jnp.asarray(step).astype(jnp.float32))


def delta_sq(hat_new: Any, hat_prev: Any) -> Array:
    """Per-worker squared L2 distance between stacked (W, ...) hat pytrees.

    Accumulated in f32 regardless of leaf dtype (mixed bf16/f32 pytrees),
    matching the quantizer's internal arithmetic so every wire_impl computes
    the identical mask.
    """
    parts = [
        jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2,
                axis=tuple(range(1, a.ndim)))
        for a, b in zip(jax.tree.leaves(hat_new), jax.tree.leaves(hat_prev))
        if a.size
    ]
    if not parts:
        leaves = jax.tree.leaves(hat_new)
        w = leaves[0].shape[0] if leaves else 0
        return jnp.zeros((w,), jnp.float32)
    return sum(parts)


def transmit_mask(hat_new: Any, hat_prev: Any, cfg: CensorConfig,
                  step: Array) -> Array:
    """(W,) bool: which workers' updates clear the censoring threshold.

    True = transmit (the quantized delta moved far enough), False = censor
    (send only the 1-bit flag; everyone keeps hat_prev).
    """
    thr = threshold(cfg, step)
    return delta_sq(hat_new, hat_prev) > thr * thr
