"""SGADMM and Q-SGADMM: the stochastic / non-convex variant (paper Sec. V-B).

Differences vs. the convex Algorithm 1:
  * each worker's local argmin is replaced by `local_iters` Adam steps on the
    stochastic augmented Lagrangian (minibatch resampled each outer iteration),
  * the dual step is damped: lam <- lam + alpha * rho * (hat_n - hat_{n+1}),
    alpha = 0.01 in the paper's experiments.

The trainer is generic over any pytree model via ravel_pytree: all chain state
is held as (N, d) flat vectors, so the quantizer/chain logic is shared with
the convex solver's structure.  Workers' local optimizations run in parallel
under vmap.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .gadmm import GADMMConfig, _quantize_rows

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SGADMMConfig:
    gadmm: GADMMConfig
    local_iters: int = 10
    local_lr: float = 1e-3
    batch_size: int = 100


class SGADMMState(NamedTuple):
    theta: Array      # (N, d)
    theta_hat: Array  # (N, d)
    lam: Array        # (N+1, d)
    radius: Array     # (N,)
    bits: Array       # (N,)
    adam_mu: Array    # (N, d)
    adam_nu: Array    # (N, d)
    adam_t: Array     # (N,)
    key: Array
    step: Array


class SGADMMTrainer:
    """Decentralized trainer for a pytree model over a worker chain."""

    def __init__(self, loss_fn: Callable, params0, n_workers: int,
                 cfg: SGADMMConfig, seed: int = 0):
        flat0, self.unravel = ravel_pytree(params0)
        self.d = flat0.size
        self.n = n_workers
        self.cfg = cfg
        self.loss_fn = loss_fn  # loss_fn(params_pytree, x, y) -> scalar
        self._flat_loss = lambda flat, x, y: loss_fn(self.unravel(flat), x, y)
        self.state = SGADMMState(
            theta=jnp.tile(flat0[None], (n_workers, 1)),
            theta_hat=jnp.zeros((n_workers, self.d)),
            lam=jnp.zeros((n_workers + 1, self.d)),
            radius=jnp.zeros((n_workers,)),
            bits=jnp.full((n_workers,), cfg.gadmm.qcfg.bits, jnp.int32),
            adam_mu=jnp.zeros((n_workers, self.d)),
            adam_nu=jnp.zeros((n_workers, self.d)),
            adam_t=jnp.zeros((n_workers,), jnp.int32),
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
        )
        self._step = jax.jit(self._make_step())

    # -- augmented Lagrangian seen by worker n (eq. 14/16 with stochastic f) --
    def _local_loss(self, flat, x, y, lam_l, lam_r, hat_l, hat_r, has_l, has_r):
        rho = self.cfg.gadmm.rho
        f = self._flat_loss(flat, x, y)
        dual = jnp.vdot(lam_l, hat_l - flat) + jnp.vdot(lam_r, flat - hat_r)
        prox = 0.5 * rho * (has_l * jnp.sum((hat_l - flat) ** 2)
                            + has_r * jnp.sum((flat - hat_r) ** 2))
        # drop dual terms on missing neighbors (lam rows are zero there anyway)
        return f + dual + prox

    def _local_adam(self, theta, mu, nu, t, x, y, lam_l, lam_r, hat_l, hat_r,
                    has_l, has_r):
        cfg = self.cfg
        b1, b2, eps = 0.9, 0.999, 1e-8
        grad_fn = jax.grad(self._local_loss)

        def body(carry, _):
            th, m, v, tt = carry
            g = grad_fn(th, x, y, lam_l, lam_r, hat_l, hat_r, has_l, has_r)
            tt = tt + 1
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** tt.astype(jnp.float32))
            vhat = v / (1 - b2 ** tt.astype(jnp.float32))
            th = th - cfg.local_lr * mhat / (jnp.sqrt(vhat) + eps)
            return (th, m, v, tt), None

        (theta, mu, nu, t), _ = jax.lax.scan(
            body, (theta, mu, nu, t), None, length=cfg.local_iters)
        return theta, mu, nu, t

    def _make_step(self):
        n, d = self.n, self.d
        cfg = self.cfg
        idx = jnp.arange(n)
        is_head = (idx % 2 == 0)
        has_l = (idx > 0).astype(jnp.float32)
        has_r = (idx < n - 1).astype(jnp.float32)

        def phase(state_tuple, xb, yb, active, key):
            theta, hat, lam, radius, bits, mu, nu, t = state_tuple
            hat_l = jnp.roll(hat, 1, axis=0) * has_l[:, None]
            hat_r = jnp.roll(hat, -1, axis=0) * has_r[:, None]
            new_theta, new_mu, new_nu, new_t = jax.vmap(self._local_adam)(
                theta, mu, nu, t, xb, yb, lam[:-1], lam[1:], hat_l, hat_r,
                has_l, has_r)
            m = active[:, None]
            theta = jnp.where(m, new_theta, theta)
            mu = jnp.where(m, new_mu, mu)
            nu = jnp.where(m, new_nu, nu)
            t = jnp.where(active, new_t, t)
            hat, radius, bits = _quantize_rows(
                theta, hat, active, key, radius, bits, cfg.gadmm)
            return theta, hat, lam, radius, bits, mu, nu, t

        def step(state: SGADMMState, xb: Array, yb: Array) -> SGADMMState:
            key, k_h, k_t = jax.random.split(state.key, 3)
            st = (state.theta, state.theta_hat, state.lam, state.radius,
                  state.bits, state.adam_mu, state.adam_nu, state.adam_t)
            st = phase(st, xb, yb, is_head, k_h)
            st = phase(st, xb, yb, ~is_head, k_t)
            theta, hat, lam, radius, bits, mu, nu, t = st
            resid = hat[:-1] - hat[1:]
            lam = lam.at[1:-1].add(cfg.gadmm.alpha * cfg.gadmm.rho * resid[: n - 1])
            lam = lam.at[0].set(0.0).at[-1].set(0.0)
            return SGADMMState(theta=theta, theta_hat=hat, lam=lam,
                               radius=radius, bits=bits, adam_mu=mu,
                               adam_nu=nu, adam_t=t, key=key,
                               step=state.step + 1)

        return step

    def train_step(self, xb: Array, yb: Array) -> None:
        """xb: (N, batch, dim), yb: (N, batch) minibatch per worker."""
        self.state = self._step(self.state, xb, yb)

    def worker_params(self, n: int):
        return self.unravel(self.state.theta[n])

    def mean_params(self):
        return self.unravel(jnp.mean(self.state.theta, axis=0))

    def bits_per_round(self) -> int:
        from .gadmm import bits_per_round

        return bits_per_round(self.cfg.gadmm, self.n, self.d)
