"""GADMM and Q-GADMM for convex objectives on a worker chain (Algorithm 1).

Faithful implementation of paper eqs. (14)-(18):

  per iteration k:
    heads  (chain pos 0,2,4,..): theta_n^{k+1} = argmin f_n + duals + prox to
                                 the *reconstructed* neighbor models hat_theta
    heads quantize (theta^{k+1} - hat_theta^k) and transmit (b, R, q)
    tails  (pos 1,3,5,..):        same, using heads' fresh hat_theta^{k+1}
    tails quantize + transmit
    all:   lambda_n^{k+1} = lambda_n^k + rho (hat_theta_n - hat_theta_{n+1})

The local problems here are quadratics f_n(t) = 0.5 ||X_n t - y_n||^2, solved in
closed form:  (X^T X + c_n rho I) t = X^T y + lam_{n-1} - lam_n
                                       + rho (hat_{n-1} + hat_{n+1})
with c_n = #neighbors.  The whole chain updates are vectorized over workers and
the iteration is jit-compiled (lax-friendly: masks instead of python branches).

With cfg.quantize=False this is exactly GADMM [23] (hat_theta == theta).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .quantizer import QuantizerConfig, _next_bits, header_bits

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GADMMConfig:
    rho: float = 24.0
    quantize: bool = True
    qcfg: QuantizerConfig = QuantizerConfig(bits=2)
    alpha: float = 1.0  # dual damping (paper uses 1 for convex, 0.01 for DNN)
    topk_frac: float = 1.0  # beyond-paper: transmit only the top-k fraction
                            # of |delta| coords per round.  Unsent coords keep
                            # their old hat value, so their residual stays in
                            # theta - hat and is retransmitted later — the
                            # hat-difference scheme IS error feedback.


class ChainState(NamedTuple):
    theta: Array       # (N, d) current primal variables
    theta_hat: Array   # (N, d) last *quantized* model of every worker, as known
                       # by its neighbors (== sender's own copy; kept in sync)
    lam: Array         # (N+1, d) duals; lam[0] == lam[N] == 0 always
    radius: Array      # (N,) R_n^{k-1}
    bits: Array        # (N,) b_n^{k-1}
    key: Array
    step: Array


def init_state(n: int, d: int, cfg: GADMMConfig, seed: int = 0) -> ChainState:
    return ChainState(
        theta=jnp.zeros((n, d)),
        theta_hat=jnp.zeros((n, d)),
        lam=jnp.zeros((n + 1, d)),
        radius=jnp.zeros((n,)),
        bits=jnp.full((n,), cfg.qcfg.bits, jnp.int32),
        key=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


class Quadratic(NamedTuple):
    """Per-worker quadratic local objectives, pre-factorized for both c values."""

    xtx: Array      # (N, d, d)
    xty: Array      # (N, d)
    minv: Array     # (N, d, d): inverse of (xtx + c_n rho I), c_n = #neighbors
    def objective(self, theta: Array) -> Array:
        """F(theta) = sum_n 0.5 theta^T XtX theta - xty.theta + const.

        (const = 0.5 ||y||^2 is added by the caller if absolute values matter.)
        """
        quad = 0.5 * jnp.einsum("nd,nde,ne->", theta, self.xtx, theta)
        lin = jnp.einsum("nd,nd->", theta, self.xty)
        return quad - lin


def make_quadratic(xs: Array, ys: Array, rho: float) -> Quadratic:
    """xs: (N, m, d) worker design matrices, ys: (N, m)."""
    n, _, d = xs.shape
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    cn = jnp.where((jnp.arange(n) == 0) | (jnp.arange(n) == n - 1), 1.0, 2.0)
    eye = jnp.eye(d)
    minv = jnp.linalg.inv(xtx + rho * cn[:, None, None] * eye[None])
    return Quadratic(xtx=xtx, xty=xty, minv=minv)


def _solve_all(q: Quadratic, lam: Array, hat: Array, rho: float) -> Array:
    """Closed-form local argmin for every worker given current duals + hats."""
    n, d = hat.shape
    has_left = (jnp.arange(n) > 0)[:, None]
    has_right = (jnp.arange(n) < n - 1)[:, None]
    hat_left = jnp.roll(hat, 1, axis=0) * has_left
    hat_right = jnp.roll(hat, -1, axis=0) * has_right
    rhs = q.xty + lam[:-1] - lam[1:] + rho * (hat_left + hat_right)
    return jnp.einsum("nde,ne->nd", q.minv, rhs)


def dequantize_rows(qlev: Array, hat_prev: Array, radius: Array,
                    bits: Array) -> Array:
    """Receiver-side reconstruction of per-row payloads (eq. 13).

    The EXACT arithmetic quantize_rows applies on the sender — the sim's
    event-driven receivers (repro.sim.worker) reconstruct through this
    function, so both ends of a link stay bit-identical by construction.
    qlev: (..., d) levels, hat_prev: (..., d), radius/bits: (...,) per row.
    """
    levels = 2.0 ** bits.astype(jnp.float32) - 1.0
    safe_r = jnp.maximum(radius, 1e-30)[..., None]
    step = 2.0 * safe_r / levels[..., None]
    hat_new = hat_prev + step * qlev - radius[..., None]
    return jnp.where(radius[..., None] > 0, hat_new, hat_prev)


def quantize_rows(theta: Array, hat_prev: Array, active: Array, key: Array,
                  radius_prev: Array, bits_prev: Array, cfg: GADMMConfig):
    """Stochastically quantize each active worker's row.

    Returns (hat_new, radius, bits, qlev) — qlev is the (N, d) wire payload
    (quantization levels); hat_new is its reconstruction via dequantize_rows
    (sender == receiver bit-sync).  Row n of every output depends ONLY on
    row n of the inputs (plus the shared key), so a single worker's
    transmission is reproducible in isolation — the property the
    event-driven simulator's actors (repro.sim) rely on.
    """
    n, d = theta.shape
    diff = theta - hat_prev
    r_new = jnp.max(jnp.abs(diff), axis=1)  # (N,) per-worker inf-norm
    # eq. 11 bit growth: single source of truth in quantizer._next_bits
    # (same dedup pattern as header_bits for the payload accounting).
    b_new = jnp.broadcast_to(
        _next_bits(cfg.qcfg, bits_prev, r_new, radius_prev), (n,))
    levels = 2.0 ** b_new.astype(jnp.float32) - 1.0
    safe_r = jnp.maximum(r_new, 1e-30)[:, None]
    step = 2.0 * safe_r / levels[:, None]
    c = (diff + r_new[:, None]) / step
    low = jnp.floor(c)
    p = c - low
    u = jax.random.uniform(key, (n, d))
    qlev = jnp.clip(low + (u < p), 0.0, levels[:, None])
    hat_new = dequantize_rows(qlev, hat_prev, r_new, b_new)
    if cfg.topk_frac < 1.0:
        # sparsify: exactly the k largest |delta| coords are transmitted (ties
        # broken by index, matching the billed k of bits_per_round); the rest
        # keep the receiver's (== sender's) previous hat value.
        k = max(int(d * cfg.topk_frac), 1)
        _, top_idx = jax.lax.top_k(jnp.abs(diff), k)  # (N, k)
        sent = jnp.zeros((n, d), bool).at[
            jnp.arange(n)[:, None], top_idx].set(True)
        hat_new = jnp.where(sent, hat_new, hat_prev)
    if not cfg.quantize:
        hat_new = theta  # GADMM: full precision "transmission"
    hat = jnp.where(active[:, None], hat_new, hat_prev)
    return (hat,
            jnp.where(active, r_new, radius_prev),
            jnp.where(active, b_new, bits_prev),
            qlev)


def _quantize_rows(theta: Array, hat_prev: Array, active: Array, key: Array,
                   radius_prev: Array, bits_prev: Array, cfg: GADMMConfig):
    """quantize_rows without the wire payload (chain/sgadmm call sites)."""
    hat, radius, bits, _ = quantize_rows(theta, hat_prev, active, key,
                                         radius_prev, bits_prev, cfg)
    return hat, radius, bits


def gadmm_step(state: ChainState, q: Quadratic, cfg: GADMMConfig) -> ChainState:
    """One full iteration (heads phase + tails phase + dual update)."""
    n, d = state.theta.shape
    idx = jnp.arange(n)
    is_head = (idx % 2 == 0)
    key, k_h, k_t = jax.random.split(state.key, 3)

    # --- heads phase ---
    theta_all = _solve_all(q, state.lam, state.theta_hat, cfg.rho)
    theta = jnp.where(is_head[:, None], theta_all, state.theta)
    hat, radius, bits = _quantize_rows(
        theta, state.theta_hat, is_head, k_h, state.radius, state.bits, cfg)

    # --- tails phase (uses heads' fresh hats) ---
    theta_all = _solve_all(q, state.lam, hat, cfg.rho)
    theta = jnp.where(is_head[:, None], theta, theta_all)
    hat, radius, bits = _quantize_rows(
        theta, hat, ~is_head, k_t, radius, bits, cfg)

    # --- dual update (eq. 18), computed from reconstructed hats ---
    resid = hat[:-1] - hat[1:]                      # (N-1, d)
    lam = state.lam.at[1:-1].add(cfg.alpha * cfg.rho * resid[: n - 1])
    lam = lam.at[0].set(0.0).at[-1].set(0.0)

    return ChainState(theta=theta, theta_hat=hat, lam=lam, radius=radius,
                      bits=bits, key=key, step=state.step + 1)


def rechain(state: ChainState, perm) -> ChainState:
    """Time-varying topology (paper Sec. II: GADMM converges under changing
    neighbors).  `perm[i]` = worker that moves to chain position i.  Primal
    state travels with the worker; edge duals are position-bound and are
    reset (a safe ADMM restart — stale duals for new edges would bias the
    first updates).  Quantizer sync state (theta_hat) also travels: both
    neighbors of any new edge reconstruct from the worker's own hat history,
    which is globally consistent by construction."""
    import jax.numpy as jnp

    perm = jnp.asarray(perm)
    return state._replace(
        theta=state.theta[perm],
        theta_hat=state.theta_hat[perm],
        lam=jnp.zeros_like(state.lam),
        radius=state.radius[perm],
        bits=state.bits[perm],
    )


def rechain_quadratic(q: Quadratic, perm, rho: float) -> Quadratic:
    """Permute per-position objectives for a new chain order and refactor
    (endpoint positions have c_n = 1, interior c_n = 2)."""
    import jax.numpy as jnp

    perm = jnp.asarray(perm)
    xtx = q.xtx[perm]
    xty = q.xty[perm]
    n, d = xty.shape
    cn = jnp.where((jnp.arange(n) == 0) | (jnp.arange(n) == n - 1), 1.0, 2.0)
    minv = jnp.linalg.inv(xtx + rho * cn[:, None, None] * jnp.eye(d)[None])
    return Quadratic(xtx=xtx, xty=xty, minv=minv)


def residuals(state: ChainState) -> tuple[Array, Array]:
    """Primal residual ||theta_n - theta_{n+1}|| (consensus violation) and a
    dual-residual proxy ||hat^k - hat^{k-1}|| is tracked by the caller."""
    r = state.theta[:-1] - state.theta[1:]
    return jnp.sqrt(jnp.sum(r * r)), jnp.max(jnp.abs(r))


def _payload_bits_per_worker(cfg: GADMMConfig, d: int) -> int:
    """Bits of one worker's broadcast payload (shared by the chain and graph
    accounting)."""
    if cfg.quantize:
        header = header_bits(cfg.qcfg.adapt_bits)
        if cfg.topk_frac < 1.0:
            import math

            k = max(int(d * cfg.topk_frac), 1)
            idx_bits = max(int(math.ceil(math.log2(max(d, 2)))), 1)
            return k * (cfg.qcfg.bits + idx_bits) + header
        return cfg.qcfg.bits * d + header
    return 32 * d


def bits_per_round(cfg: GADMMConfig, n: int, d: int) -> int:
    """Total bits all N workers transmit in one iteration.

    Q-GADMM payload per worker = b*d + header, with the header shared with
    quantizer.payload_bits (quantizer.header_bits: the R f32 and the b i32
    the payload always carries — 64 + b*d for fixed global-radius bits).
    """
    return n * _payload_bits_per_worker(cfg, d)


# ===== generalized topologies + censored transmissions (CQ-GGADMM) =========
#
# The chain implementation above is the paper-faithful fast path.  The graph
# variant below runs the same two-phase Gauss-Seidel sweep on ANY connected
# bipartite topology (core.topology: ring / star / 2d-torus / arbitrary),
# with one dual variable per EDGE instead of per chain link, and optional
# censored transmissions (core.censor): a worker whose freshly quantized
# model moved less than tau*xi^k keeps silent — every endpoint (itself
# included) reuses the previous hat, so sender==receiver bit-sync survives.
# It is the single-host reference the distributed trainer's topology/censor
# modes are validated against (tests/test_convergence.py).


class GraphState(NamedTuple):
    theta: Array       # (N, d) current primals
    theta_hat: Array   # (N, d) last *transmitted* quantized models
    lam: Array         # (E, d) edge duals, canonical head -> tail
    radius: Array      # (N,) R_n of the last transmitted round
    bits: Array        # (N,) b_n of the last transmitted round
    sent: Array        # (N,) bool: did worker n transmit last iteration?
    key: Array
    step: Array


def graph_init_state(topo, d: int, cfg: GADMMConfig,
                     seed: int = 0) -> GraphState:
    n = topo.n
    return GraphState(
        theta=jnp.zeros((n, d)),
        theta_hat=jnp.zeros((n, d)),
        lam=jnp.zeros((topo.num_edges, d)),
        radius=jnp.zeros((n,)),
        bits=jnp.full((n,), cfg.qcfg.bits, jnp.int32),
        sent=jnp.zeros((n,), bool),
        key=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


def make_graph_quadratic(xs: Array, ys: Array, rho: float, topo) -> Quadratic:
    """Per-worker quadratics factored with c_n = deg(n) from the topology."""
    n, _, d = xs.shape
    assert n == topo.n, (n, topo.n)
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    cn = jnp.asarray(topo.degree, jnp.float32)
    eye = jnp.eye(d)
    minv = jnp.linalg.inv(xtx + rho * cn[:, None, None] * eye[None])
    return Quadratic(xtx=xtx, xty=xty, minv=minv)


def graph_consts(topo, layout: str = "edge"):
    """Static jnp views of the topology used inside the jitted step.

    Always carries the O(E) directed edge-index arrays from
    ``topology.edge_index`` (``d_src``/``d_dst``/``d_edge``, sorted by
    (dst, src)).  The dense port-style operators (``adj``, ``inc`` —
    O(N^2) / O(N*E) memory and aggregation work) are materialized only
    when ``layout='port'`` asks for them: at production worker counts
    (10^4+) the dense matrices alone would dwarf the model state, and the
    edge layout never touches them.  The two layouts are
    bitwise-identical on CPU (property-tested in tests/test_gadmm.py)
    because the segment_sum adds each worker's neighbor terms in the same
    ascending order the dense row reduction uses."""
    import numpy as np

    from .topology import edge_index

    n = topo.n
    eidx = edge_index(topo)
    tc = dict(
        head=jnp.asarray(topo.head_mask),
        adj=None,
        inc=None,
        e_head=jnp.asarray(topo.edges[:, 0] if topo.num_edges else
                           np.zeros((0,), np.int64)),
        e_tail=jnp.asarray(topo.edges[:, 1] if topo.num_edges else
                           np.zeros((0,), np.int64)),
        n=n,
        d_src=jnp.asarray(eidx.src),
        d_dst=jnp.asarray(eidx.dst),
        d_edge=jnp.asarray(eidx.edge),
    )
    if layout == "port":
        inc = np.zeros((n, max(topo.num_edges, 1)), np.float32)
        for e, (h, t) in enumerate(topo.edges):
            inc[h, e] = inc[t, e] = 1.0
        tc["adj"] = jnp.asarray(topo.adjacency(), jnp.float32)
        tc["inc"] = jnp.asarray(inc)
    return tc


_graph_consts = graph_consts  # pre-PR-4 name


def _graph_solve_all(q: Quadratic, lam: Array, hat: Array, rho: float,
                     tc, layout: str = "edge") -> Array:
    """Closed-form local argmin for every worker on the graph.

    Node n minimizes f_n + s_n * sum_e<n> <lam_e, theta_n - hat_nbr> +
    rho/2 sum_nbr ||theta_n - hat_nbr||^2 with s_n = +1 for heads (the edge
    dual's canonical orientation is head -> tail), giving
      (XtX + deg_n rho I) theta_n = Xty_n - s_n sum_e lam_e
                                    + rho sum_nbr hat_nbr.

    layout='edge' (default) aggregates the neighbor sums with one
    segment_sum over the 2E directed edges — O(E*d) work.  layout='port'
    is the pre-refactor dense form (inc @ lam, adj @ hat — O(N*E*d) /
    O(N^2*d)), kept as the comparator for the bitwise-equivalence
    property test and the benchmark baseline.
    """
    sign = jnp.where(tc["head"], 1.0, -1.0)[:, None]
    if layout == "port":
        assert tc["adj"] is not None, \
            "layout='port' needs graph_consts(topo, layout='port')"
        lam_sum = tc["inc"] @ lam if lam.shape[0] else jnp.zeros_like(hat)
        nbr_sum = tc["adj"] @ hat
    else:
        assert layout == "edge", layout
        n = tc["n"]
        if lam.shape[0]:
            # directed edges sorted by (dst, src): worker n's terms are
            # added in ascending neighbor order, matching the dense row
            # reduction bit for bit on CPU
            lam_sum = jax.ops.segment_sum(lam[tc["d_edge"]], tc["d_dst"],
                                          num_segments=n,
                                          indices_are_sorted=True)
            nbr_sum = jax.ops.segment_sum(hat[tc["d_src"]], tc["d_dst"],
                                          num_segments=n,
                                          indices_are_sorted=True)
        else:
            # degenerate graphs (W=1): no edges, no neighbor terms
            lam_sum = jnp.zeros_like(hat)
            nbr_sum = jnp.zeros_like(hat)
    rhs = q.xty - sign * lam_sum + rho * nbr_sum
    return jnp.einsum("nde,ne->nd", q.minv, rhs)


def graph_phase(theta: Array, hat: Array, lam: Array, radius: Array,
                bits: Array, active: Array, key: Array, *, q: Quadratic,
                cfg: GADMMConfig, tc, step: Array, censor=None,
                layout: str = "edge"):
    """One phase of the graph sweep: the `active` group solves its local
    problems, quantizes, and (optionally) censors.

    Returns (theta, hat, radius, bits, sent, qlev).  Row n of every output
    depends only on row n of the inputs, n's neighbor rows of `hat`
    (through the adjacency-masked proximal term), and n's incident rows of
    `lam` — so a single worker can replay its own row exactly from a local
    view that has garbage in all unrelated rows.  This is the contract the
    event-driven simulator's actors (repro.sim.worker.GraphActor) build on:
    the lockstep graph_step below and the message-by-message simulator run
    the SAME function and are bit-identical under an ideal network.
    """
    from .censor import transmit_mask

    theta_all = _graph_solve_all(q, lam, hat, cfg.rho, tc, layout=layout)
    theta = jnp.where(active[:, None], theta_all, theta)
    hat_new, r_new, b_new, qlev = quantize_rows(
        theta, hat, active, key, radius, bits, cfg)
    if censor is not None:
        sent = active & transmit_mask(hat_new, hat, censor, step)
        hat_new = jnp.where(sent[:, None], hat_new, hat)
        r_new = jnp.where(sent, r_new, radius)
        b_new = jnp.where(sent, b_new, bits)
    else:
        sent = active
    return theta, hat_new, r_new, b_new, sent, qlev


def graph_dual_update(lam: Array, hat: Array, cfg: GADMMConfig, tc,
                      edge_mask: Array | None = None) -> Array:
    """Per-edge damped dual update (eq. 18): lam_e += a*rho*(h_head - h_tail).

    `edge_mask` (E,) freezes edges when 0 — the simulator uses it to stop
    updating duals on links whose far endpoint dropped out.
    """
    if not lam.shape[0]:
        return lam
    resid = hat[tc["e_head"]] - hat[tc["e_tail"]]
    if edge_mask is not None:
        resid = resid * edge_mask[:, None]
    return lam + cfg.alpha * cfg.rho * resid


def graph_step(state: GraphState, q: Quadratic, cfg: GADMMConfig, topo,
               censor=None, layout: str = "edge") -> GraphState:
    """One censored GGADMM/CQ-GGADMM iteration on an arbitrary bipartite
    topology (heads phase + tails phase + per-edge dual update).

    `censor` is an optional core.censor.CensorConfig; when set, a phase's
    freshly quantized hats are committed only for workers whose update
    clears the decaying threshold — everyone else's neighbors (and the
    worker itself) keep the previous hat, and the round is recorded in
    state.sent for wire accounting (graph_bits_per_round).

    `layout` selects the neighbor-aggregation state layout: 'edge' (the
    O(E) segment_sum default) or 'port' (pre-refactor dense operators) —
    bitwise-identical on CPU, property-tested in tests/test_gadmm.py.
    """
    tc = graph_consts(topo, layout=layout)
    is_head = tc["head"]
    key, k_h, k_t = jax.random.split(state.key, 3)

    theta, hat, radius, bits, sent_h, _ = graph_phase(
        state.theta, state.theta_hat, state.lam, state.radius, state.bits,
        is_head, k_h, q=q, cfg=cfg, tc=tc, step=state.step, censor=censor,
        layout=layout)
    theta, hat, radius, bits, sent_t, _ = graph_phase(
        theta, hat, state.lam, radius, bits,
        ~is_head, k_t, q=q, cfg=cfg, tc=tc, step=state.step, censor=censor,
        layout=layout)
    lam = graph_dual_update(state.lam, hat, cfg, tc)

    return GraphState(theta=theta, theta_hat=hat, lam=lam, radius=radius,
                      bits=bits, sent=sent_h | sent_t, key=key,
                      step=state.step + 1)


def graph_bits_per_round(cfg: GADMMConfig, topo, d: int,
                         sent=None, censored: bool = False):
    """Bits all workers transmit in one graph iteration (broadcast
    accounting, same per-worker payload rule as bits_per_round).

    Without censoring every worker broadcasts once; with censoring only the
    workers with sent=True pay the payload, everyone pays FLAG_BITS for the
    censor flag.  `sent` may be a traced (N,) bool array — the result is
    then a traced scalar, summable across rounds."""
    from .censor import FLAG_BITS

    per = _payload_bits_per_worker(cfg, d)
    if not censored:
        return topo.n * per
    assert sent is not None, "censored accounting needs the sent mask"
    return jnp.sum(sent.astype(jnp.float32)) * per + topo.n * FLAG_BITS
