"""Serving runtime: batched prefill + KV/state-cached decode on a
('data', 'model') mesh.

The Server owns the sharding policy: parameters are tensor-parallel over
'model' (replicated over 'data'), request batches and caches are sharded over
'data', and logits come back batch-sharded.  All model math lives in
repro.models; this module only places it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import serve_mesh
from repro.models import layers as L

from . import sharding as sh


def serve_view(mesh: Mesh) -> Mesh:
    """('data','model') serving view of any production mesh (pods fold into
    the data axis); identity on an already-2D mesh."""
    return serve_mesh(mesh)


def _batch_dim_spec(n: int, mesh: Mesh, extra_dims: int) -> P:
    """P('data', None...) when the batch divides the data axis, else fully
    replicated (tiny/ragged batches)."""
    data = mesh.shape.get("data", 1)
    lead = "data" if (data > 1 and n % data == 0) else None
    return P(lead, *(None,) * extra_dims)


def cache_specs(cache, mesh: Mesh, batch_size: int,
                seq_parallel: bool = False):
    """PartitionSpecs for a decode cache pytree.

    The batch dim (found by size, searching dims 1, 2, 0 — caches are stacked
    (layers, batch, ...) or (periods, inner, batch, ...)) shards over 'data';
    a trailing heads dim shards over 'model' when divisible.  seq_parallel
    instead shards the key/value sequence dim (-3) over 'data' — the
    batch=1, 500k-context decode layout.
    """
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def leaf(a):
        spec = [None] * a.ndim
        if seq_parallel and a.ndim >= 4 and a.shape[-3] > 1 \
                and a.shape[-3] % data == 0:
            spec[a.ndim - 3] = "data"
        elif data > 1:
            for i in (1, 2, 0):
                if i < a.ndim and a.shape[i] == batch_size \
                        and batch_size % data == 0:
                    spec[i] = "data"
                    break
        if (model > 1 and a.ndim >= 2 and spec[a.ndim - 2] is None
                and a.shape[-2] >= model and a.shape[-2] % model == 0):
            spec[a.ndim - 2] = "model"
        return P(*spec)

    return jax.tree.map(leaf, cache)


class Server:
    """Inference server for one model on a serving mesh.

    jit_prefill / jit_decode return AOT-friendly jitted callables whose
    in/out shardings pin params to tensor-parallel layout and activations,
    logits, and caches to batch-sharded layout.  Template arguments may be
    ShapeDtypeStructs (dry-run lowering) or concrete arrays.
    """

    def __init__(self, *, model, cfg, mesh: Mesh, batch_size: int):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.batch_size = batch_size

    # ---------------------------------------------------------- shardings --
    def param_specs(self, params):
        return sh.tree_specs(params, sh.leaf_serve_spec, self.mesh)

    def param_shardings(self, params):
        return sh.tree_shardings(self.param_specs(params), self.mesh)

    def batch_shardings(self, batch):
        return jax.tree.map(
            lambda a: NamedSharding(self.mesh, _batch_dim_spec(
                a.shape[0], self.mesh, a.ndim - 1)), batch)

    def _logits_sharding(self, batch_size: int):
        return NamedSharding(self.mesh, _batch_dim_spec(
            batch_size, self.mesh, 1))

    def _act_sharding(self, batch_size: int):
        return NamedSharding(self.mesh, _batch_dim_spec(
            batch_size, self.mesh, 2))

    # ------------------------------------------------------------ prefill --
    def _prefill_fn(self, batch_size: int):
        model, cfg = self.model, self.cfg

        def fn(params, batch):
            L.set_activation_sharding(self._act_sharding(batch_size))
            try:
                if cfg.family in ("vlm", "audio"):
                    logits, cache = model.prefill(params, batch, cfg)
                else:
                    logits, cache = model.prefill(params, batch["tokens"], cfg)
            finally:
                L.set_activation_sharding(None)
            return logits, cache

        return fn

    def jit_prefill(self, params, batch, batch_size: int = 0):
        """-> jitted (params, batch) -> (last-token logits (B, vocab), cache).

        batch_size defaults to the Server's; passing one overrides every
        layout decision consistently (logits, activations, cache)."""
        batch_size = batch_size or self.batch_size
        fn = self._prefill_fn(batch_size)
        cache_struct = jax.eval_shape(fn, params, batch)[1]
        cshard = sh.tree_shardings(
            cache_specs(cache_struct, self.mesh, batch_size), self.mesh)
        return jax.jit(
            fn,
            in_shardings=(self.param_shardings(params),
                          self.batch_shardings(batch)),
            out_shardings=(self._logits_sharding(batch_size), cshard))

    # ------------------------------------------------------------- decode --
    def jit_decode(self, params, cache, batch_size: int = 0,
                   seq_parallel: bool = False):
        """-> jitted (params, token (B,), cache, pos (B,)) -> (logits, cache)."""
        model, cfg = self.model, self.cfg
        batch_size = batch_size or self.batch_size

        def fn(params, token, cache, pos):
            L.set_activation_sharding(self._act_sharding(batch_size))
            try:
                return model.decode_step(params, token, cache, pos, cfg)
            finally:
                L.set_activation_sharding(None)

        cshard = sh.tree_shardings(
            cache_specs(cache, self.mesh, batch_size, seq_parallel), self.mesh)
        tok_shard = NamedSharding(self.mesh, _batch_dim_spec(
            batch_size, self.mesh, 0))
        return jax.jit(
            fn,
            in_shardings=(self.param_shardings(params), tok_shard, cshard,
                          tok_shard),
            out_shardings=(self._logits_sharding(batch_size), cshard))
