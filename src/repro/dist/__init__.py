"""Distributed runtime: sharded Q-GADMM training + serving.

`qgadmm` implements paper Algorithm 1 (eqs. 14-18) across the 'worker' axis of
a factored ('worker', 'fsdp', 'model') mesh: each worker's replica is
FSDP+TP sharded inside its device group, and the chain exchange travels as
uint8 collective-permutes.  `serve` is the inference-side counterpart
(batched prefill + decode on a ('data', 'model') mesh).
"""
from . import qgadmm, serve, sharding

__all__ = ["qgadmm", "serve", "sharding"]
