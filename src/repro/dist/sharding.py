"""Sharding-rule helpers shared by the trainer and the server.

A *rule* is an ordered list of (dim, axes) pairs: "try to shard dimension
`dim` over the mesh axes `axes`".  `_assign` applies the first rules whose
dimension is divisible by the axes' total size (GSPMD can pad uneven shards,
but we only do that when explicitly asked via allow_uneven — e.g. the
head-padding perf toggle, where padded heads are output-masked so the
computation stays exact).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _assign(shape, rules, mesh: Mesh, allow_uneven: bool = False) -> P:
    """PartitionSpec for `shape` from ordered (dim, axes) rules.

    A rule fires when the dimension is divisible by the axes' product size, or
    when allow_uneven=True and the dimension is at least that size (GSPMD pads
    the ragged last shard).  Each mesh axis and each dimension is used at most
    once; unmatched dimensions stay replicated.
    """
    spec: list = [None] * len(shape)
    used: set[str] = set()
    for dim, axes in rules:
        d = dim if dim >= 0 else len(shape) + dim
        if d < 0 or d >= len(shape) or spec[d] is not None:
            continue
        if any(a in used for a in axes):
            continue
        size = _axes_size(mesh, axes)
        if size <= 1:
            continue
        if shape[d] % size != 0 and not (allow_uneven and shape[d] >= size):
            continue
        spec[d] = axes[0] if len(axes) == 1 else tuple(axes)
        used.update(axes)
    return P(*spec)


# RoPE splits/concats the trailing head_dim of q/k, and XLA:CPU's SPMD
# partitioner miscompiles that pattern when head_dim is sharded (verified:
# O(1) absolute error vs replicated).  Keep every dim that RoPE touches — the
# last dim of the q/k/v projections and biases — replicated.
_ROPE_LAST_DIM_KEYS = frozenset({"wq", "wk", "wv", "bq", "bk", "bv"})


def _leaf_key(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key is not None:
            return str(key)
    return ""


def _candidate_dims(shape, start: int, leaf_key: str):
    """Shardable inner dims, largest first; drops the RoPE head_dim."""
    dims = list(range(start, len(shape)))
    if leaf_key in _ROPE_LAST_DIM_KEYS and len(dims) > 1:
        dims = dims[:-1]
    return sorted(dims, key=lambda i: -shape[i])


def leaf_train_spec(shape, mesh: Mesh, allow_uneven: bool = False,
                    leaf_key: str = "") -> P:
    """Spec for one stacked trainer leaf (W, ...): worker on dim 0, the
    largest remaining dim FSDP-sharded, the next largest tensor-parallel."""
    if len(shape) == 0:
        return P()
    order = _candidate_dims(shape, 1, leaf_key)
    rules = [(0, ("worker",))]
    if order:
        rules.append((order[0], ("fsdp",)))
        for i in order[1:]:
            rules.append((i, ("model",)))
    return _assign(shape, rules, mesh, allow_uneven=allow_uneven)


def leaf_edge_spec(shape, mesh: Mesh, allow_uneven: bool = False,
                   leaf_key: str = "") -> P:
    """Spec for one directed-edge slab leaf (2E, ...) of the trainer's
    edge-indexed neighbor state: fully replicated.  The leading edge dim
    cannot ride the worker axis (a worker's incident edge count is its
    degree — ragged), and sharding the inner model dims trips the same
    XLA:CPU SPMD partitioner miscompile documented for the in-shard codec:
    the row-subset gather/scatter decode that commits received rows into
    the slab produces O(1) garbage when the slab output is repartitioned
    to a model-sharded layout at the step boundary.  The decode therefore
    pins its operands replicated, and the slab spec must agree so the step
    output is not resharded back through the broken partition path."""
    del allow_uneven, leaf_key  # replicated regardless of shape
    return P(*(None,) * len(shape))


def leaf_serve_spec(shape, mesh: Mesh, allow_uneven: bool = False,
                    leaf_key: str = "") -> P:
    """Serving spec for one parameter leaf: largest dim tensor-parallel over
    'model', everything else replicated (params are replicated over 'data')."""
    order = _candidate_dims(shape, 0, leaf_key)
    rules = [(i, ("model",)) for i in order]
    return _assign(shape, rules, mesh, allow_uneven=allow_uneven)


def tree_specs(tree, leaf_rule, mesh: Mesh, **kw):
    """Map a per-leaf rule over a pytree of arrays / ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, a: leaf_rule(a.shape, mesh, leaf_key=_leaf_key(path),
                                  **kw), tree)


def tree_shardings(tree_or_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_or_specs,
                        is_leaf=lambda x: isinstance(x, P))


def pad_to_multiple(n: int, m: int) -> int:
    return int(math.ceil(n / max(m, 1)) * max(m, 1))
