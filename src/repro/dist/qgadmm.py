"""Sharded multi-worker Q-GADMM trainer (paper Algorithm 1, eqs. 14-18).

Workers live on the 'worker' axis of a factored ('worker', 'fsdp', 'model')
mesh (repro.launch.mesh.factor_mesh); each worker's replica of the model is
FSDP+TP sharded inside its device group.  One train step is the Q-SGADMM
iteration (paper Sec. IV / V-B):

  * heads (chain positions 0, 2, ...) run `local_iters` Adam steps on the
    stochastic augmented Lagrangian of eq. 14 (their own data shard plus dual
    and proximal terms to the *reconstructed* neighbor models),
  * heads quantize theta - theta_hat_prev with the stochastic quantizer of
    repro.core.quantizer and transmit (q, R, b) — the uint8 level tensor is
    flattened into one wire buffer per worker and exchanged with both chain
    neighbors over jax.lax.ppermute (the compiled HLO carries u8
    collective-permutes: only quantized payloads touch the interconnect),
  * tails (positions 1, 3, ...) do the same against the heads' fresh hats,
  * every worker applies the damped dual update of eq. 18
    (lam += alpha * rho * (hat_n - hat_{n+1})).

Both endpoints of every edge reconstruct the transmitted model with
repro.core.quantizer.dequantize_tensor from their own synchronized copy of the
sender's previous hat, so sender and receiver stay bit-identical — the
algorithm's key invariant.

`mode="jacobi"` collapses the two masked phases into one simultaneous update
of all workers (benchmarks/bench_jacobi.py measures the trade-off), and
`num_workers=1` degenerates to plain FSDP data-parallel Adam with no chain
collectives at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.gadmm import GADMMConfig, bits_per_round
from repro.core.quantizer import _next_bits, dequantize_tensor, quantize_tensor
from repro.kernels.pack.ref import pack4_ref, unpack4_ref

from . import sharding as sh

Array = jax.Array

_ADAM_B1, _ADAM_B2, _ADAM_EPS = 0.9, 0.999, 1e-8


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static configuration of the distributed Q-GADMM trainer.

    num_workers: GADMM chain length == size of the mesh 'worker' axis.
    gadmm:       rho / quantizer / dual-damping configuration (shared with the
                 single-host reference implementations in repro.core).
    local_iters: Adam steps per worker per phase (paper Sec. IV, Q-SGADMM).
    local_lr:    local Adam learning rate.
    mode:        'gauss-seidel' (paper: masked head/tail phases) or 'jacobi'
                 (one simultaneous phase; half the per-step compute).
    microbatches:gradient accumulation inside each local step.
    radius_mode: 'global' = one R per worker per round (paper-faithful);
                 'per_tensor' = one R per parameter tensor (tighter ranges,
                 beyond-paper; costs 32 bits/tensor of header).
    state_dtype: cast chain state (theta/hat/duals) to this dtype (e.g.
                 bf16); None keeps the model's param dtype.
    uneven_shard:allow GSPMD-padded uneven sharding of parameter dims.
    pack_wire:   nibble-pack the uint8 wire when bits <= 4 (halves bytes).
    seq_shard:   additionally shard the batch sequence dim over 'model'.
    """

    num_workers: int
    gadmm: GADMMConfig
    local_iters: int = 1
    local_lr: float = 1e-3
    mode: str = "gauss-seidel"
    microbatches: int = 1
    radius_mode: str = "global"
    state_dtype: Any = None
    uneven_shard: bool = False
    pack_wire: bool = False
    seq_shard: bool = False

    def __post_init__(self):
        assert self.mode in ("gauss-seidel", "jacobi"), self.mode
        assert self.radius_mode in ("global", "per_tensor"), self.radius_mode
        # The chain wire is always dense; top-k sparsification only exists in
        # the single-host reference (gadmm._quantize_rows) so far.
        assert self.gadmm.topk_frac >= 1.0, \
            "topk sparsification is not supported by the distributed trainer"
        if self.pack_wire and self.gadmm.quantize:
            q = self.gadmm.qcfg
            max_b = q.max_bits if q.adapt_bits else q.bits
            assert max_b <= 4, "pack_wire needs <= 4-bit levels"


class DistState(NamedTuple):
    """Replicated-per-worker chain state; every pytree leaf is stacked with a
    leading (num_workers,) dim sharded over the mesh 'worker' axis."""

    theta: Any      # current primal parameters
    theta_hat: Any  # own last-quantized model (== what neighbors hold)
    hat_left: Any   # reconstruction of left neighbor's hat (zeros at w=0)
    hat_right: Any  # reconstruction of right neighbor's hat (zeros at w=W-1)
    lam_left: Any   # dual on edge (w-1, w); row 0 stays zero
    lam_right: Any  # dual on edge (w, w+1); row W-1 stays zero
    radius: Array   # (W,) global mode | (W, n_tensors) per_tensor mode
    bits: Array     # (W,) int32
    opt_mu: Any     # local Adam first moment
    opt_nu: Any     # local Adam second moment
    opt_t: Array    # (W,) int32 Adam step counts
    key: Array      # PRNG key (stochastic rounding)
    step: Array     # () int32


def init_state(init_fn: Callable[[Array], Any], key: Array,
               dcfg: DistConfig) -> DistState:
    """State at k=0: every worker starts from the same init, hats at zero
    (the paper initializes theta_hat^0 = 0)."""
    w = dcfg.num_workers
    k_init, k_state = jax.random.split(key)
    params = init_fn(k_init)
    if dcfg.state_dtype is not None:
        params = jax.tree.map(
            lambda a: a.astype(dcfg.state_dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    theta = jax.tree.map(
        lambda a: jnp.tile(a[None], (w,) + (1,) * a.ndim), params)
    zeros = lambda: jax.tree.map(jnp.zeros_like, theta)
    n_tensors = len(jax.tree.leaves(theta))
    radius = (jnp.zeros((w,), jnp.float32) if dcfg.radius_mode == "global"
              else jnp.zeros((w, n_tensors), jnp.float32))
    return DistState(
        theta=theta, theta_hat=zeros(), hat_left=zeros(), hat_right=zeros(),
        lam_left=zeros(), lam_right=zeros(), radius=radius,
        bits=jnp.full((w,), dcfg.gadmm.qcfg.bits, jnp.int32),
        opt_mu=zeros(), opt_nu=zeros(),
        opt_t=jnp.zeros((w,), jnp.int32),
        key=k_state, step=jnp.zeros((), jnp.int32))


# ------------------------------------------------------------- tree utils ---
def _bmask(m: Array, leaf: Array) -> Array:
    return m.reshape(m.shape + (1,) * (leaf.ndim - m.ndim))


def _twhere(m: Array, a, b):
    return jax.tree.map(lambda x, y: jnp.where(_bmask(m, x), x, y), a, b)


def _tvdot(a, b) -> Array:
    parts = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b))
    return sum(parts) if parts else jnp.zeros(())


def _tsqnorm(a, b) -> Array:
    parts = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(
            (x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2), a, b))
    return sum(parts) if parts else jnp.zeros(())


class QGADMMTrainer:
    """Decentralized trainer for one model over the factored worker mesh.

    model: a repro.models module (init / loss_fn(params, batch, cfg)).
    cfg:   its ArchConfig.
    dcfg:  DistConfig above.
    worker_mesh: ('worker', 'fsdp', 'model') mesh from factor_mesh.
    """

    def __init__(self, model, cfg, dcfg: DistConfig, worker_mesh: Mesh):
        self.model = model
        self.cfg = cfg
        self.dcfg = dcfg
        self.mesh = worker_mesh

    # ------------------------------------------------------------ specs ----
    def batch_specs(self, batch):
        seq_axes = ("model",) if self.dcfg.seq_shard else None

        def leaf(a):
            rules = [(0, ("worker",)), (1, ("fsdp",))]
            if seq_axes and a.ndim >= 3:
                rules.append((2, seq_axes))
            return sh._assign(a.shape, rules, self.mesh)

        return jax.tree.map(leaf, batch)

    def state_specs(self, state: DistState) -> DistState:
        au = self.dcfg.uneven_shard
        pspec = functools.partial(sh.tree_specs, leaf_rule=sh.leaf_train_spec,
                                  mesh=self.mesh, allow_uneven=au)
        wspec = P("worker") if self.dcfg.num_workers > 1 else P(None)
        return DistState(
            theta=pspec(state.theta), theta_hat=pspec(state.theta_hat),
            hat_left=pspec(state.hat_left), hat_right=pspec(state.hat_right),
            lam_left=pspec(state.lam_left), lam_right=pspec(state.lam_right),
            radius=(wspec if state.radius.ndim == 1
                    else P(*wspec, None)),
            bits=wspec, opt_mu=pspec(state.opt_mu), opt_nu=pspec(state.opt_nu),
            opt_t=wspec, key=P(None), step=P())

    def _shardings(self, specs):
        return sh.tree_shardings(specs, self.mesh)

    def place(self, state: DistState, batch):
        """device_put state + batch onto the worker mesh."""
        state = jax.device_put(state, self._shardings(self.state_specs(state)))
        batch = jax.tree.map(jnp.asarray, batch)
        batch = jax.device_put(batch, self._shardings(self.batch_specs(batch)))
        return state, batch

    # ------------------------------------------------------------- wire ----
    def _group_size(self) -> int:
        return int(self.mesh.shape.get("fsdp", 1)
                   * self.mesh.shape.get("model", 1))

    def _flatten_wire(self, leaves, dtype):
        """[(W, ...)] -> one (W, D_pad) buffer (+ optional nibble packing)."""
        w = self.dcfg.num_workers
        flat = jnp.concatenate([l.reshape(w, -1).astype(dtype) for l in leaves],
                               axis=1)
        if dtype == jnp.uint8 and self.dcfg.pack_wire:
            flat = jax.vmap(pack4_ref)(flat)
        pad = sh.pad_to_multiple(flat.shape[1], self._group_size())
        if pad != flat.shape[1]:
            flat = jnp.pad(flat, ((0, 0), (0, pad - flat.shape[1])))
        return flat

    def _unflatten_wire(self, wire, templates):
        """(W, D_pad) -> [(W, ...)] leaves shaped like `templates`."""
        n = sum(int(np.prod(t.shape[1:])) for t in templates)
        if wire.dtype == jnp.uint8 and self.dcfg.pack_wire:
            packed_len = 128 * (-(-n // 256))  # pack4_ref wire length
            wire = jax.vmap(lambda p: unpack4_ref(p[:packed_len], n))(wire)
        out, off = [], 0
        for t in templates:
            size = int(np.prod(t.shape[1:]))
            out.append(wire[:, off:off + size].reshape(t.shape))
            off += size
        return out

    def _make_exchange(self, sharded: bool):
        """payload pytree of (W, ...) arrays -> (from_left, from_right).

        from_left[w] = payload[w-1] (zeros at w=0); from_right[w] =
        payload[w+1] (zeros at w=W-1).  The sharded path sends each device's
        shard to the matching device of the neighbor worker group with
        jax.lax.ppermute — uint8 payloads stay uint8 on the wire.
        """
        w = self.dcfg.num_workers
        if not sharded:
            def exchange(payload):
                down = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [jnp.zeros_like(x[:1]), x[:-1]], axis=0), payload)
                up = jax.tree.map(
                    lambda x: jnp.concatenate(
                        [x[1:], jnp.zeros_like(x[:1])], axis=0), payload)
                return down, up
            return exchange

        mesh = self.mesh
        perm_r = [(i, i + 1) for i in range(w - 1)]
        perm_l = [(i + 1, i) for i in range(w - 1)]

        def spec_of(a):
            if a.ndim == 2 and a.shape[1] % self._group_size() == 0:
                return P("worker", ("fsdp", "model"))
            return P("worker", *(None,) * (a.ndim - 1))

        def exchange(payload):
            specs = jax.tree.map(spec_of, payload)

            def body(p):
                from_left = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, "worker", perm_r), p)
                from_right = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, "worker", perm_l), p)
                return from_left, from_right

            return shard_map(body, mesh=mesh, in_specs=(specs,),
                             out_specs=(specs, specs),
                             check_rep=False)(payload)

        return exchange

    # ------------------------------------------------------- quantization --
    def _quantize_all(self, theta, hat, bits_prev, radius_prev, key):
        """Quantize every worker row; returns (q_leaves, hat_new, r_new, b_new).

        r_new: (W,) in global mode, (W, L) per_tensor.  Bit adaptation (paper
        eq. 11) always tracks the global radius ratio.
        """
        qcfg = self.dcfg.gadmm.qcfg
        w = self.dcfg.num_workers
        leaves = jax.tree.leaves(theta)
        treedef = jax.tree.structure(theta)
        hat_leaves = treedef.flatten_up_to(hat)
        per_leaf_r = jnp.stack([
            jax.vmap(lambda x, h: jnp.max(jnp.abs(
                x.astype(jnp.float32) - h.astype(jnp.float32))))(x, h)
            for x, h in zip(leaves, hat_leaves)], axis=1)  # (W, L)
        r_global = jnp.max(per_leaf_r, axis=1)             # (W,)
        if qcfg.adapt_bits:
            r_prev = (radius_prev if radius_prev.ndim == 1
                      else jnp.max(radius_prev, axis=1))
            b_new = _next_bits(qcfg, bits_prev, r_global, r_prev)  # (W,)
        else:
            b_new = jnp.full((w,), qcfg.bits, jnp.int32)
        r_new = r_global if self.dcfg.radius_mode == "global" else per_leaf_r
        keys = jax.random.split(key, max(len(leaves), 1))
        qs, hats = [], []
        for i, (x, h) in enumerate(zip(leaves, hat_leaves)):
            r_i = r_global if r_new.ndim == 1 else r_new[:, i]
            q, hh = jax.vmap(
                lambda xx, hh_, kk, rr, bb: quantize_tensor(
                    xx, hh_, kk, radius=rr, bits=bb)
            )(x, h, jax.random.split(keys[i], w), r_i, b_new)
            qs.append(q)
            hats.append(hh)
        return (qs, jax.tree.unflatten(treedef, hats), r_new, b_new)

    def _dequantize_all(self, q_leaves, hat_copy, radius, bits):
        """Receiver-side reconstruction against the stored neighbor hats."""
        treedef = jax.tree.structure(hat_copy)
        hat_leaves = treedef.flatten_up_to(hat_copy)
        outs = []
        for i, (q, h) in enumerate(zip(q_leaves, hat_leaves)):
            r_i = radius if radius.ndim == 1 else radius[:, i]
            outs.append(jax.vmap(
                lambda qq, hh, rr, bb: dequantize_tensor(
                    qq, hh, radius=rr, bits=bb))(q, h, r_i, bits))
        return jax.tree.unflatten(treedef, outs)

    # ------------------------------------------------------------- step ----
    def _data_loss(self, theta_w, batch_w):
        mb = self.dcfg.microbatches
        if mb <= 1:
            return self.model.loss_fn(theta_w, batch_w, self.cfg)
        split = jax.tree.map(
            lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]), batch_w)

        def body(acc, b):
            return acc + self.model.loss_fn(theta_w, b, self.cfg), None

        total, _ = jax.lax.scan(body, jnp.zeros(()), split)
        return total / mb

    def _local_loss(self, theta_w, batch_w, lam_l, lam_r, hat_l, hat_r,
                    has_l, has_r):
        """Stochastic augmented Lagrangian of eq. 14/16 for one worker."""
        rho = self.dcfg.gadmm.rho
        f = self._data_loss(theta_w, batch_w)
        dual = (_tvdot(lam_l, jax.tree.map(jnp.subtract, hat_l, theta_w))
                + _tvdot(lam_r, jax.tree.map(jnp.subtract, theta_w, hat_r)))
        prox = 0.5 * rho * (has_l * _tsqnorm(hat_l, theta_w)
                            + has_r * _tsqnorm(theta_w, hat_r))
        return f + dual + prox, f

    def _local_opt(self, theta, mu, nu, t, batch_w, lam_l, lam_r, hat_l,
                   hat_r, has_l, has_r):
        """local_iters Adam steps on the augmented Lagrangian (one worker)."""
        lr = self.dcfg.local_lr
        grad_fn = jax.value_and_grad(self._local_loss, has_aux=True)

        def body(carry, _):
            th, m, v, tt = carry
            (_, f), g = grad_fn(th, batch_w, lam_l, lam_r, hat_l, hat_r,
                                has_l, has_r)
            tt = tt + 1
            tf = tt.astype(jnp.float32)
            m = jax.tree.map(
                lambda mm, gg: _ADAM_B1 * mm + (1 - _ADAM_B1) * gg, m, g)
            v = jax.tree.map(
                lambda vv, gg: _ADAM_B2 * vv + (1 - _ADAM_B2) * gg * gg, v, g)
            th = jax.tree.map(
                lambda t_, mm, vv: (t_ - lr * (mm / (1 - _ADAM_B1 ** tf))
                                    / (jnp.sqrt(vv / (1 - _ADAM_B2 ** tf))
                                       + _ADAM_EPS)).astype(t_.dtype),
                th, m, v)
            return (th, m, v, tt), f

        (theta, mu, nu, t), fs = jax.lax.scan(
            body, (theta, mu, nu, t), None, length=self.dcfg.local_iters)
        return theta, mu, nu, t, fs[0]

    def make_train_step(self):
        """Unsharded (single-process) reference step: identical math to the
        sharded step, neighbor exchange via array shifts instead of ppermute."""
        return self._build_step(sharded=False)

    def jit_train_step(self, state: DistState, batch):
        """Jitted sharded step; state/batch may be arrays or ShapeDtypeStructs
        (AOT lowering for dry runs)."""
        ss = self._shardings(self.state_specs(state))
        bs = self._shardings(self.batch_specs(batch))
        return jax.jit(self._build_step(sharded=True),
                       in_shardings=(ss, bs), out_shardings=(ss, None))

    def _build_step(self, sharded: bool):
        dcfg = self.dcfg
        g = dcfg.gadmm
        w = dcfg.num_workers
        if sharded and "worker" in self.mesh.shape:
            assert self.mesh.shape["worker"] == w, (
                f"mesh worker axis {self.mesh.shape['worker']} != "
                f"num_workers {w}")
        idx = np.arange(w)
        has_l = jnp.asarray(idx > 0)
        has_r = jnp.asarray(idx < w - 1)
        is_head = jnp.asarray(idx % 2 == 0)
        all_on = jnp.ones((w,), bool)
        exchange = self._make_exchange(sharded) if w > 1 else None

        def phase(st, batch, active, key):
            (theta, hat, hat_l, hat_r, lam_l, lam_r, radius, bits,
             mu, nu, t) = st
            new_theta, new_mu, new_nu, new_t, f0 = jax.vmap(self._local_opt)(
                theta, mu, nu, t, batch, lam_l, lam_r, hat_l, hat_r,
                has_l.astype(jnp.float32), has_r.astype(jnp.float32))
            theta = _twhere(active, new_theta, theta)
            mu = _twhere(active, new_mu, mu)
            nu = _twhere(active, new_nu, nu)
            t = jnp.where(active, new_t, t)

            if g.quantize:
                q_leaves, hat_new, r_new, b_new = self._quantize_all(
                    theta, hat, bits, radius, key)
                hat = _twhere(active, hat_new, hat)
                radius = jnp.where(_bmask(active, r_new), r_new, radius)
                bits = jnp.where(active, b_new, bits)
                payload = {"wire": self._flatten_wire(q_leaves, jnp.uint8),
                           "radius": r_new, "bits": b_new}
            else:
                # full-precision GADMM: track the would-be radius for metrics,
                # then "transmit" theta itself (hat == theta).
                per_leaf_r = jnp.stack([
                    jax.vmap(lambda x, h: jnp.max(jnp.abs(
                        x.astype(jnp.float32) - h.astype(jnp.float32))))(x, h)
                    for x, h in zip(jax.tree.leaves(theta),
                                    jax.tree.leaves(hat))], axis=1)  # (W, L)
                hat = _twhere(active, theta, hat)
                r_new = (per_leaf_r.max(1) if radius.ndim == 1 else per_leaf_r)
                radius = jnp.where(_bmask(active, r_new), r_new, radius)
                payload = {"wire": self._flatten_wire(
                    jax.tree.leaves(hat), jnp.float32)}

            if exchange is not None:
                from_l, from_r = exchange(payload)
                # active[w-1] / active[w+1]: did my neighbor transmit?
                sent_l = jnp.concatenate([jnp.zeros((1,), bool), active[:-1]])
                sent_r = jnp.concatenate([active[1:], jnp.zeros((1,), bool)])
                templates = jax.tree.leaves(theta)
                if g.quantize:
                    ql = self._unflatten_wire(from_l["wire"], templates)
                    qr = self._unflatten_wire(from_r["wire"], templates)
                    hat_l = _twhere(sent_l & has_l, self._dequantize_all(
                        ql, hat_l, from_l["radius"], from_l["bits"]), hat_l)
                    hat_r = _twhere(sent_r & has_r, self._dequantize_all(
                        qr, hat_r, from_r["radius"], from_r["bits"]), hat_r)
                else:
                    hl_leaves = self._unflatten_wire(from_l["wire"], templates)
                    hr_leaves = self._unflatten_wire(from_r["wire"], templates)
                    treedef = jax.tree.structure(theta)
                    cast = lambda ls, ref: jax.tree.unflatten(
                        treedef, [l.astype(r.dtype) for l, r in
                                  zip(ls, jax.tree.leaves(ref))])
                    hat_l = _twhere(sent_l & has_l, cast(hl_leaves, hat_l),
                                    hat_l)
                    hat_r = _twhere(sent_r & has_r, cast(hr_leaves, hat_r),
                                    hat_r)
            return (theta, hat, hat_l, hat_r, lam_l, lam_r, radius, bits,
                    mu, nu, t), f0

        def step(state: DistState, batch):
            key, k1, k2 = jax.random.split(state.key, 3)
            st = (state.theta, state.theta_hat, state.hat_left,
                  state.hat_right, state.lam_left, state.lam_right,
                  state.radius, state.bits, state.opt_mu, state.opt_nu,
                  state.opt_t)
            if dcfg.mode == "gauss-seidel" and w > 1:
                st, f0 = phase(st, batch, is_head, k1)
                st, _ = phase(st, batch, ~is_head, k2)
            else:
                st, f0 = phase(st, batch, all_on, k1)
            (theta, hat, hat_l, hat_r, lam_l, lam_r, radius, bits,
             mu, nu, t) = st

            # damped dual update (eq. 18) from reconstructed hats; both ends
            # of each edge apply the same increment, keeping duals in sync.
            scale = g.alpha * g.rho
            lam_r = jax.tree.map(
                lambda l, a, b: l + scale * _bmask(has_r, l)
                * (a.astype(l.dtype) - b.astype(l.dtype)), lam_r, hat, hat_r)
            lam_l = jax.tree.map(
                lambda l, a, b: l + scale * _bmask(has_l, l)
                * (a.astype(l.dtype) - b.astype(l.dtype)), lam_l, hat_l, hat)

            resid = jnp.sqrt(sum(jax.tree.leaves(jax.tree.map(
                lambda a, b: jnp.sum(_bmask(has_r, a)
                                     * (a.astype(jnp.float32)
                                        - b.astype(jnp.float32)) ** 2),
                hat, hat_r))) + 0.0)
            metrics = {
                "loss": jnp.mean(f0),
                "consensus_resid": resid,
                "radius_mean": jnp.mean(radius),
                "bits_mean": jnp.mean(bits.astype(jnp.float32)),
                "wire_bits_per_round": jnp.asarray(
                    self.wire_bits_per_round(theta), jnp.float32),
            }
            new_state = DistState(
                theta=theta, theta_hat=hat, hat_left=hat_l, hat_right=hat_r,
                lam_left=lam_l, lam_right=lam_r, radius=radius, bits=bits,
                opt_mu=mu, opt_nu=nu, opt_t=t, key=key, step=state.step + 1)
            return new_state, metrics

        return step

    def wire_bits_per_round(self, theta) -> int:
        """Chain traffic per iteration under the unified payload accounting
        (repro.core.quantizer.payload_bits / gadmm.bits_per_round).
        per_tensor radius mode transmits one extra f32 R per tensor beyond
        the single global R that bits_per_round already bills."""
        leaves = jax.tree.leaves(theta)
        d = sum(int(np.prod(l.shape[1:])) for l in leaves)
        total = bits_per_round(self.dcfg.gadmm, self.dcfg.num_workers, d)
        if self.dcfg.gadmm.quantize and self.dcfg.radius_mode == "per_tensor":
            total += self.dcfg.num_workers * 32 * (len(leaves) - 1)
        return total
