"""Sharded multi-worker Q-GADMM trainer (paper Algorithm 1, eqs. 14-18).

Workers live on the 'worker' axis of a factored ('worker', 'fsdp', 'model')
mesh (repro.launch.mesh.factor_mesh); each worker's replica of the model is
FSDP+TP sharded inside its device group.  One train step is the Q-SGADMM
iteration (paper Sec. IV / V-B):

  * heads (chain positions 0, 2, ...) run `local_iters` Adam steps on the
    stochastic augmented Lagrangian of eq. 14 (their own data shard plus dual
    and proximal terms to the *reconstructed* neighbor models),
  * heads quantize theta - theta_hat_prev and transmit (q, R, b),
  * tails (positions 1, 3, ...) do the same against the heads' fresh hats,
  * every worker applies the damped dual update of eq. 18
    (lam += alpha * rho * (hat_n - hat_{n+1})).

The quantized exchange is FUSED onto one flat wire buffer per worker: all
parameter leaves are flattened into a single (W, D_pad) row per worker, and
one fused quantize->pack->ppermute->unpack->dequantize pipeline replaces
the L small per-leaf ops.  In the sharded step both the codec and the
nibble packing run INSIDE shard_map — every device quantizes and packs
exactly the wire slab it owns (the production TPU layout, and it keeps the
codec's pad/reshape/slice internals away from the SPMD partitioner, which
XLA:CPU miscompiles; see the RoPE note in dist.sharding).
`DistConfig.wire_impl` selects the codec implementation — 'jnp' (pure-jnp
reference), 'pallas' (Pallas kernels from repro.kernels.{quantize,pack} in
interpret mode, for CPU), or 'pallas_compiled' (compiled Pallas, TPU).
All three consume one shared uniform draw over the wire buffer, so they
are bit-identical; per_tensor radius mode expands its per-leaf radii into
per-element values with a segment-scalar gather before the fused call.
When the effective bit width is <= 4 each device nibble-packs its shard
(kernels/pack wire format, `packed_len` bytes per shard) right before the
jax.lax.ppermute, halving the bytes on the interconnect; `pack_wire=None`
(the default) enables this automatically.

Both endpoints of every edge reconstruct the transmitted model with the same
flat-buffer arithmetic from their own synchronized copy of the sender's
previous hat, so sender and receiver stay bit-identical — the algorithm's
key invariant.

`overlap=True` double-buffers the gauss-seidel exchange: the heads' payload
is put on the wire and the tails run their local Adam iterations against the
*previous* neighbor hats while it is in flight (one-exchange staleness,
beyond-paper), letting XLA hide the chain latency behind compute.

`mode="jacobi"` collapses the two masked phases into one simultaneous update
of all workers (benchmarks/bench_jacobi.py measures the trade-off), and
`num_workers=1` degenerates to plain FSDP data-parallel Adam with no chain
collectives at all.

Beyond the paper's chain, `DistConfig.topology` runs the same two-phase
sweep on any connected bipartite worker graph (core.topology: 'ring',
'star', '2d-torus', or an explicit Topology).  A proper edge coloring
(Koenig) splits the edges into matchings, and each matching is exactly
one jax.lax.ppermute permutation — the collective schedule is the
canonical core.topology.edge_schedule, derived from the graph, never
hard-coded +-1 shifts.

State layout (O(C) -> O(E)).  Neighbor state is EDGE-INDEXED: the
topology's 2E directed edges (core.topology.edge_index, sorted by
(dst, src)) each own one slab row, so `DistState.hat_edge[d]` is what
worker dst(d) knows about src(d)'s hat and `lam_edge[d]` is dst(d)'s
mirror of the shared edge dual (canonical head -> tail orientation; both
directions of an edge hold bitwise-equal mirrors in lockstep).  The old
port-dense layout kept C = max-degree full (W, ...) tuples — O(W*C*D)
memory and per-step dequantize/dual work even at degree 1; the slabs are
O(E*D), and `edge_index.slot` projects them back to per-(worker, color)
port views wherever the math is per-worker (the local loss) or the
transport is per-color (the sharded ppermute exchange).  The projection
is exact: gathered rows are the stored rows, missing ports read as the
zeros they always were.

`DistConfig.staleness = S > 0` replaces the per-color exchange barrier
with an explicit send / recv-start / recv-done pipeline: each round's
merged head+tail payload is SENT into an S-deep in-flight ring buffer
(`DistState.inbox` — recv-start), and the round-(k-S) entry is decoded
into the edge slabs at the top of round k (recv-done), so every worker
computes against neighbor hats that are exactly S rounds stale.  Duals
update against the matching S-stale snapshot of the worker's OWN hat
(`DistState.hat_lag`, decoded from the same payload stream), so both
endpoints of an edge keep pairing the same (head, tail) hat rounds and
the dual mirrors stay synchronized — the trainer-side analog of
sim.worker's fresh-edge dual gating, with the first S pipeline-fill
rounds gated off.  Wire accounting bills a payload on the round it is
sent, never the round it is consumed.  S=0 is the barriered schedule,
bitwise-identical to the pre-refactor port-dense trainer
(tests/test_wire_path.py replays committed goldens to pin this).

`DistConfig.censor` adds CQ-GGADMM censored transmissions (core.censor): a
worker whose freshly quantized model moved less than tau*xi^k in L2 keeps
silent for the round — the wire carries only a 1-bit censor flag, every
receiver (and the sender itself) reuses the previous hat, and because the
skip decision is computed from quantized values both ends already share,
the sender==receiver bit-sync invariant survives.  `wire_bits_per_round`
then becomes data-dependent: skipped links are billed FLAG_BITS instead of
the payload row, and the step reports a `skip_rate` metric.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import censor as censor_mod
from repro.core.censor import CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import (LayerwiseConfig, _next_bits, allocate_bits,
                                  header_bits)
from repro.core.topology import (Topology, build_topology, edge_index,
                                 edge_schedule)
from repro.kernels.pack import ops as pack_ops
from repro.kernels.pack.ref import packed_len
from repro.kernels.quantize import quantize as q_kernel
from repro.kernels.quantize import ref as q_ref

from . import sharding as sh

Array = jax.Array

_ADAM_B1, _ADAM_B2, _ADAM_EPS = 0.9, 0.999, 1e-8


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Static configuration of the distributed Q-GADMM trainer.

    num_workers: GADMM chain length == size of the mesh 'worker' axis.
    gadmm:       rho / quantizer / dual-damping configuration (shared with the
                 single-host reference implementations in repro.core).
    local_iters: Adam steps per worker per phase (paper Sec. IV, Q-SGADMM).
    local_lr:    local Adam learning rate.
    mode:        'gauss-seidel' (paper: masked head/tail phases) or 'jacobi'
                 (one simultaneous phase; half the per-step compute).
    microbatches:gradient accumulation inside each local step.
    radius_mode: 'global' = one R per worker per round (paper-faithful);
                 'per_tensor' = one R per parameter tensor (tighter ranges,
                 beyond-paper; costs 32 bits/tensor of header).
    state_dtype: cast chain state (theta/hat/duals) to this dtype (e.g.
                 bf16); None keeps the model's param dtype.
    uneven_shard:allow GSPMD-padded uneven sharding of parameter dims.
    pack_wire:   nibble-pack the uint8 wire when bits <= 4 (halves bytes).
                 None (default) = auto: packed whenever the effective bit
                 width (max_bits if adaptive, else bits) is <= 4.
    seq_shard:   additionally shard the batch sequence dim over 'model'.
    wire_impl:   codec for the fused quantize/pack wire path — 'jnp'
                 (pure-jnp reference), 'pallas' (kernels in interpret mode,
                 CPU), 'pallas_compiled' (compiled Pallas, TPU).  All three
                 are bit-identical (shared uniform-draw convention).
    overlap:     double-buffer the gauss-seidel exchange: tails run their
                 local iterations against the previous neighbor hats while
                 the heads' payload is in flight (one-exchange staleness).
    topology:    worker graph — 'chain' (paper), 'ring', 'star', 'torus2d',
                 or an explicit core.topology.Topology (any connected
                 bipartite graph).  Determines the phases' head/tail split
                 and the ppermute schedule (one permutation per edge color).
    censor:      optional core.censor.CensorConfig: transmit a phase's
                 quantized delta only when ||hat_new - hat_prev||_2 >
                 tau*xi^k; skipped links cost 1 flag bit on the wire.
    staleness:   S = 0 (default): barriered per-color exchange, every
                 round consumes this round's payloads.  S > 0: pipelined
                 send/recv-start/recv-done exchange — payloads spend S
                 rounds in flight (DistState.inbox) and every worker
                 computes against neighbor hats exactly S rounds old,
                 duals fresh-edge-gated onto matching S-stale snapshots
                 (the trainer promotion of repro.sim's bounded-staleness
                 async schedule; see the module docstring).
    participation: per-round Bernoulli rate of each worker taking part
                 (1.0 = everyone, the default — that path is bitwise
                 identical to the pre-participation trainer).  Each round
                 draws a (W,) mask by folding a constant into the round
                 key (every worker derives the same mask — shared setup
                 knowledge, no extra wire traffic).  An absent worker
                 skips its local iterations and transmits nothing; its
                 neighbors drop the frozen hat from their neighbor sums
                 with degree-renormalized weights (deg / #participating
                 neighbors — exactly 1.0 whenever everyone is present,
                 so fully-present rounds are unbiased AND bit-stable),
                 and an edge's dual updates only when BOTH endpoints
                 participate, keeping the lam mirrors synchronized.
                 Composes with censoring (absent != censored: a censored
                 worker computed but stayed silent) and with the
                 staleness pipeline (the mask gates the round's compute
                 and its in-flight payload alike).
    layerwise:   optional core.quantizer.LayerwiseConfig (L-FGADMM,
                 arXiv:1911.03654): each pytree leaf gets its own bit
                 width, exchange period and censor threshold, with an
                 optional per-round bit-budget controller
                 (quantizer.allocate_bits) reallocating a fixed payload
                 budget toward the leaves whose residuals moved most.
                 Forces radius_mode='per_tensor' (per-leaf radii are the
                 layerwise codec's native sideband) and requires the
                 quantized wire.  An unsent leaf rides the payload with
                 radius 0 — the codec's R == 0 guard makes it a no-op on
                 both endpoints, so receivers hold the leaf's last hat and
                 the sender==receiver bit-sync invariant survives.
                 Composes with censor (worker-level threshold on the
                 leaf-masked candidate commit), staleness (the masked
                 radius rides the inbox ring) and participation.
    telemetry:   extend the step metrics with the observability counters
                 (repro.obs): billed wire bits split into payload/header/
                 flags, per-worker transmit mask and directed-link
                 counts, dual-residual norm, participation popcount,
                 per-leaf bit allocation under layerwise.  All of them
                 are pure functions of values the step already computes —
                 the state stream is bitwise-identical either way; False
                 keeps the original minimal metrics dict.
    check_invariants: run the repro.obs.checks live invariants on this
                 trainer's drained metric windows (the launch CLIs also
                 honor env REPRO_CHECK=1).
    """

    num_workers: int
    gadmm: GADMMConfig
    local_iters: int = 1
    local_lr: float = 1e-3
    mode: str = "gauss-seidel"
    microbatches: int = 1
    radius_mode: str = "global"
    state_dtype: Any = None
    uneven_shard: bool = False
    pack_wire: bool | None = None
    seq_shard: bool = False
    wire_impl: str = "jnp"
    overlap: bool = False
    topology: Any = "chain"
    censor: CensorConfig | None = None
    staleness: int = 0
    participation: float = 1.0
    layerwise: LayerwiseConfig | None = None
    telemetry: bool = True
    check_invariants: bool = False

    def __post_init__(self):
        assert 0.0 < self.participation <= 1.0, self.participation
        assert self.mode in ("gauss-seidel", "jacobi"), self.mode
        assert self.radius_mode in ("global", "per_tensor"), self.radius_mode
        if self.layerwise is not None:
            assert self.gadmm.quantize, \
                "layerwise bit allocation needs the quantized wire"
            object.__setattr__(self, "radius_mode", "per_tensor")
        build_topology(self.topology, self.num_workers)  # validate early
        assert self.wire_impl in ("jnp", "pallas", "pallas_compiled"), \
            self.wire_impl
        assert not (self.overlap and self.mode != "gauss-seidel"), \
            "overlap (double-buffered exchange) only applies to the " \
            "two-phase gauss-seidel mode"
        assert self.staleness >= 0, self.staleness
        assert self.staleness == 0 or (self.mode == "gauss-seidel"
                                       and not self.overlap), \
            "staleness > 0 pipelines the two-phase gauss-seidel exchange " \
            "(jacobi and overlap have their own schedules)"
        # The chain wire is always dense; top-k sparsification only exists in
        # the single-host reference (gadmm._quantize_rows) so far.
        assert self.gadmm.topk_frac >= 1.0, \
            "topk sparsification is not supported by the distributed trainer"
        q = self.gadmm.qcfg
        max_b = q.max_bits if q.adapt_bits else q.bits
        lw = self.layerwise
        if lw is not None:
            # effective max bit width across leaves: the dense simulated
            # exchange packs the WHOLE row, so all leaves must fit a nibble
            if lw.adapt_bits or lw.budget_bits is not None:
                max_b = lw.max_bits
            elif lw.bits is None:
                max_b = q.bits
            elif isinstance(lw.bits, int):
                max_b = lw.bits
            else:
                max_b = max(int(b) for b in lw.bits)
        if self.pack_wire is None:
            object.__setattr__(
                self, "pack_wire", bool(self.gadmm.quantize and max_b <= 4))
        if self.pack_wire and self.gadmm.quantize:
            assert max_b <= 4, "pack_wire needs <= 4-bit levels"


class DistState(NamedTuple):
    """Replicated-per-worker chain state; parameter-shaped pytree leaves are
    stacked with a leading (num_workers,) dim sharded over the mesh
    'worker' axis.

    Neighbor state is EDGE-INDEXED (O(E), not O(W*C)): the topology's 2E
    directed edges (core.topology.edge_index, sorted by (dst, src)) each
    own one slab row.  ``hat_edge`` leaf rows are what dst(d) knows about
    src(d)'s hat; ``lam_edge`` rows are dst(d)'s mirror of the shared edge
    dual (canonical head -> tail orientation — in lockstep both directions
    of an edge are bitwise-equal).  ``edge_index.slot[w, c]`` projects a
    slab back to the per-(worker, edge-color) port view where needed.  A
    chain has 2E = 2(W-1) rows, a star 2(W-1), a 2d-torus 4W — always
    2E = sum of degrees, never W * max-degree.

    ``inbox``/``hat_lag`` exist only at staleness S > 0: the S-deep ring of
    in-flight payload rounds ({wire, radius, bits, sent} stacked with a
    leading (S,) dim) and the worker's own hat delayed S rounds (decoded
    from the same payload stream the neighbors decode — the consistent
    snapshot the dual update pairs against)."""

    theta: Any      # current primal parameters
    theta_hat: Any  # own last-quantized model (== what neighbors hold)
    hat_edge: Any   # directed-edge slab (2E, ...): dst's view of src's hat
    lam_edge: Any   # directed-edge slab (2E, ...): dst's dual mirror
    radius: Array   # (W,) global mode | (W, n_tensors) per_tensor mode
    bits: Array     # (W,) int32 | (W, n_tensors) layerwise mode
    opt_mu: Any     # local Adam first moment
    opt_nu: Any     # local Adam second moment
    opt_t: Array    # (W,) int32 Adam step counts
    key: Array      # PRNG key (stochastic rounding)
    step: Array     # () int32
    inbox: Any = () # staleness > 0: S-deep in-flight payload ring
    hat_lag: Any = ()  # staleness > 0: own hat, S rounds delayed


def init_state(init_fn: Callable[[Array], Any], key: Array,
               dcfg: DistConfig) -> DistState:
    """State at k=0: every worker starts from the same init, hats at zero
    (the paper initializes theta_hat^0 = 0)."""
    w = dcfg.num_workers
    topo = build_topology(dcfg.topology, w)
    k_init, k_state = jax.random.split(key)
    params = init_fn(k_init)
    if dcfg.state_dtype is not None:
        params = jax.tree.map(
            lambda a: a.astype(dcfg.state_dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    theta = jax.tree.map(
        lambda a: jnp.tile(a[None], (w,) + (1,) * a.ndim), params)
    zeros = lambda: jax.tree.map(jnp.zeros_like, theta)
    n_tensors = len(jax.tree.leaves(theta))
    radius = (jnp.zeros((w,), jnp.float32) if dcfg.radius_mode == "global"
              else jnp.zeros((w, n_tensors), jnp.float32))
    if dcfg.layerwise is not None:
        sizes = [int(np.prod(l.shape)) for l in jax.tree.leaves(params)]
        lw_bits, _, _ = dcfg.layerwise.resolve(sizes, dcfg.gadmm.qcfg.bits)
        bits0 = jnp.tile(jnp.asarray(lw_bits, jnp.int32)[None], (w, 1))
    else:
        bits0 = jnp.full((w,), dcfg.gadmm.qcfg.bits, jnp.int32)
    de = 2 * topo.num_edges
    edge_zeros = lambda: jax.tree.map(
        lambda a: jnp.zeros((de,) + a.shape, a.dtype), params)
    inbox, hat_lag = (), ()
    if dcfg.staleness > 0:
        s = dcfg.staleness
        d = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        wire_dtype = jnp.uint8 if dcfg.gadmm.quantize else jnp.float32
        inbox = {
            "wire": jnp.zeros((s, w, d), wire_dtype),
            "radius": jnp.zeros((s,) + radius.shape, jnp.float32),
            "bits": jnp.zeros((s,) + bits0.shape, jnp.int32),
            # all-False sent flags = the pipeline-fill rounds decode to
            # no-ops, exactly like S censored rounds
            "sent": jnp.zeros((s, w), bool),
        }
        hat_lag = zeros()
    return DistState(
        theta=theta, theta_hat=zeros(),
        hat_edge=edge_zeros(), lam_edge=edge_zeros(),
        radius=radius, bits=bits0,
        opt_mu=zeros(), opt_nu=zeros(),
        opt_t=jnp.zeros((w,), jnp.int32),
        key=k_state, step=jnp.zeros((), jnp.int32),
        inbox=inbox, hat_lag=hat_lag)


# ------------------------------------------------------------- tree utils ---
def _bmask(m: Array, leaf: Array) -> Array:
    return m.reshape(m.shape + (1,) * (leaf.ndim - m.ndim))


def _twhere(m: Array, a, b):
    return jax.tree.map(lambda x, y: jnp.where(_bmask(m, x), x, y), a, b)


def _tvdot(a, b) -> Array:
    parts = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b))
    return sum(parts) if parts else jnp.zeros(())


def _tsqnorm(a, b) -> Array:
    parts = jax.tree.leaves(jax.tree.map(
        lambda x, y: jnp.sum(
            (x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2), a, b))
    return sum(parts) if parts else jnp.zeros(())


def _leaf_sizes(leaves) -> list[int]:
    """Flat per-worker size of each stacked (W, ...) leaf."""
    return [int(np.prod(l.shape[1:])) for l in leaves]


class QGADMMTrainer:
    """Decentralized trainer for one model over the factored worker mesh.

    model: a repro.models module (init / loss_fn(params, batch, cfg)).
    cfg:   its ArchConfig.
    dcfg:  DistConfig above.
    worker_mesh: ('worker', 'fsdp', 'model') mesh from factor_mesh.
    """

    def __init__(self, model, cfg, dcfg: DistConfig, worker_mesh: Mesh):
        self.model = model
        self.cfg = cfg
        self.dcfg = dcfg
        self.mesh = worker_mesh
        self.topo: Topology = build_topology(dcfg.topology, dcfg.num_workers)
        pmask_np = self.topo.port >= 0                   # (W, C) static
        self.pmask = jnp.asarray(pmask_np, jnp.float32)
        self.port_on = [jnp.asarray(pmask_np[:, c])
                        for c in range(self.topo.num_ports)]
        self.is_head = jnp.asarray(self.topo.head_mask)
        self.sign = jnp.where(self.is_head, 1.0, -1.0).astype(jnp.float32)
        # Directed-edge tables for the O(E) neighbor-state slabs.
        self.eidx = edge_index(self.topo)
        self._d_src = jnp.asarray(self.eidx.src, jnp.int32)    # (2E,)
        self._d_dst = jnp.asarray(self.eidx.dst, jnp.int32)    # (2E,)
        self._d_sign = jnp.asarray(self.eidx.sign_dst)         # (2E,) f32
        self._d_color = jnp.asarray(self.eidx.color, jnp.int32)  # (2E,)
        slot = self.eidx.slot                                  # (W, C) np
        ports = self.topo.num_ports
        # slot clamped to 0 for the port-view gather (masked to zeros after)
        self._view_idx = [jnp.asarray(np.where(slot[:, c] >= 0, slot[:, c],
                                               0), np.int32)
                          for c in range(ports)]
        # layerwise: per-leaf tables cache + the per-leaf eq. 11 config
        self._lw_cache: dict = {}
        lw = dcfg.layerwise
        self._lw_qcfg = (dataclasses.replace(
            dcfg.gadmm.qcfg, adapt_bits=True, max_bits=lw.max_bits,
            bits=min(dcfg.gadmm.qcfg.bits, lw.max_bits))
            if lw is not None and lw.adapt_bits else None)

    def _lw_tables(self, sizes: tuple):
        """Resolved per-leaf (bits, periods, taus) device tables for a flat
        leaf-size tuple (static; cached per distinct pytree shape)."""
        if sizes not in self._lw_cache:
            bits, periods, taus = self.dcfg.layerwise.resolve(
                list(sizes), self.dcfg.gadmm.qcfg.bits)
            self._lw_cache[sizes] = (
                jnp.asarray(bits, jnp.int32),
                jnp.asarray(periods, jnp.int32),
                None if taus is None else jnp.asarray(taus, jnp.float32))
        return self._lw_cache[sizes]

    def _replicate(self, tree):
        """Pin every leaf of a pytree to the fully replicated layout (a
        with_sharding_constraint; only meaningful inside the sharded jit)."""
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, P(*(None,) * jnp.ndim(x)))),
            tree)

    # ------------------------------------------------------------ views ----
    def _port_view(self, slab):
        """Edge-slab pytree (2E, ...) -> tuple over edge colors of stacked
        (W, ...) trees (the port-dense layout the per-worker local loss is
        written against).  Exact: active rows are gathered slab rows,
        missing ports read as the zeros those rows always held in the
        port-dense layout."""
        outs = []
        for c in range(self.topo.num_ports):
            idx, on = self._view_idx[c], self.port_on[c]
            outs.append(jax.tree.map(
                lambda s: jnp.where(_bmask(on, s[idx]), s[idx],
                                    jnp.zeros_like(s[idx])), slab))
        return tuple(outs)

    def port_views(self, state: DistState) -> dict:
        """Public projection of the edge-indexed neighbor state back to the
        pre-refactor per-(worker, color) port views — the layout-independent
        surface the golden replay tier and the sim parity tests compare."""
        return {"hat_nbr": self._port_view(state.hat_edge),
                "lam_nbr": self._port_view(state.lam_edge)}

    # ------------------------------------------------------------ specs ----
    def batch_specs(self, batch):
        seq_axes = ("model",) if self.dcfg.seq_shard else None

        def leaf(a):
            rules = [(0, ("worker",)), (1, ("fsdp",))]
            if seq_axes and a.ndim >= 3:
                rules.append((2, seq_axes))
            return sh._assign(a.shape, rules, self.mesh)

        return jax.tree.map(leaf, batch)

    def state_specs(self, state: DistState) -> DistState:
        au = self.dcfg.uneven_shard
        pspec = functools.partial(sh.tree_specs, leaf_rule=sh.leaf_train_spec,
                                  mesh=self.mesh, allow_uneven=au)
        espec = functools.partial(sh.tree_specs, leaf_rule=sh.leaf_edge_spec,
                                  mesh=self.mesh, allow_uneven=au)
        wspec = P("worker") if self.dcfg.num_workers > 1 else P(None)
        inbox, hat_lag = (), ()
        if self.dcfg.staleness > 0:
            inbox = {
                "wire": P(None, *wspec, None),
                "radius": (P(None, *wspec) if state.inbox["radius"].ndim == 2
                           else P(None, *wspec, None)),
                "bits": (P(None, *wspec) if state.inbox["bits"].ndim == 2
                         else P(None, *wspec, None)),
                "sent": P(None, *wspec),
            }
            hat_lag = pspec(state.hat_lag)
        return DistState(
            theta=pspec(state.theta), theta_hat=pspec(state.theta_hat),
            hat_edge=espec(state.hat_edge), lam_edge=espec(state.lam_edge),
            radius=(wspec if state.radius.ndim == 1
                    else P(*wspec, None)),
            bits=(wspec if state.bits.ndim == 1 else P(*wspec, None)),
            opt_mu=pspec(state.opt_mu), opt_nu=pspec(state.opt_nu),
            opt_t=wspec, key=P(None), step=P(), inbox=inbox, hat_lag=hat_lag)

    def _shardings(self, specs):
        return sh.tree_shardings(specs, self.mesh)

    def place(self, state: DistState, batch):
        """device_put state + batch onto the worker mesh."""
        state = jax.device_put(state, self._shardings(self.state_specs(state)))
        batch = jax.tree.map(jnp.asarray, batch)
        batch = jax.device_put(batch, self._shardings(self.batch_specs(batch)))
        return state, batch

    # ------------------------------------------------------------- wire ----
    def _group_size(self) -> int:
        return int(self.mesh.shape.get("fsdp", 1)
                   * self.mesh.shape.get("model", 1))

    def _pack_impl(self) -> str:
        return "ref" if self.dcfg.wire_impl == "jnp" else self.dcfg.wire_impl

    def _flatten_rows(self, leaves, dtype):
        """[(R, ...)] -> one (R, D) buffer (zero-size leaves contribute 0
        columns).  R is whatever leading dim the leaves carry — the worker
        count on the stacked wire path, a per-color edge-row count on the
        slab decode path."""
        rows = leaves[0].shape[0] if leaves else self.dcfg.num_workers
        cols = [l.reshape(rows, -1).astype(dtype) for l in leaves]
        if not cols:
            return jnp.zeros((rows, 0), dtype)
        return jnp.concatenate(cols, axis=1)

    def _pad_wire(self, flat):
        """Zero-pad columns so each row splits evenly across the worker's
        (fsdp, model) device group."""
        pad = sh.pad_to_multiple(flat.shape[1], self._group_size())
        if pad != flat.shape[1]:
            flat = jnp.pad(flat, ((0, 0), (0, pad - flat.shape[1])))
        return flat

    def _finish_wire(self, flat):
        """(W, D) codec output -> the exchanged (W, D_pad) buffer.

        Nibble packing happens per device shard INSIDE the exchange's
        shard_map (see _make_exchange), never here: the SPMD partitioner
        miscompiles the strided pack reshape/stack pattern when the wire
        columns are sharded (same XLA:CPU bug family as the RoPE
        split/concat note in dist.sharding), and per-shard packing is what
        a real transport would do anyway."""
        return self._pad_wire(flat)

    def _flatten_wire(self, leaves, dtype):
        """[(W, ...)] -> exchanged (W, D_pad) buffer (flatten + pad)."""
        return self._finish_wire(self._flatten_rows(leaves, dtype))

    def _strip_wire(self, wire, n: int):
        """Received (W, D_pad) uint8 levels -> (W, n) (drop group padding;
        the exchange already unpacked its per-shard nibbles)."""
        return wire[:, :n]

    def _unflatten_wire(self, wire, templates):
        """(R, D_pad) float buffer -> [(R, ...)] leaves with the templates'
        per-row shapes (full-precision GADMM wire; no packing).  R follows
        the buffer, not the templates."""
        out, off = [], 0
        rows = wire.shape[0]
        for t in templates:
            size = int(np.prod(t.shape[1:]))
            out.append(wire[:, off:off + size].reshape((rows,) + t.shape[1:]))
            off += size
        return out

    def _unflatten_cast(self, flat, like_leaves, treedef):
        """(W, D) f32 buffer -> pytree of leaves cast to each leaf's dtype —
        the same final cast quantize_tensor/dequantize_tensor apply, so the
        fused path keeps the sender==receiver bit-sync per leaf."""
        out, off = [], 0
        for t in like_leaves:
            size = int(np.prod(t.shape[1:]))
            out.append(flat[:, off:off + size].reshape(t.shape)
                       .astype(t.dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    def _port_perms(self) -> list[list[tuple[int, int]]]:
        """One ppermute permutation per edge color — the canonical
        core.topology.edge_schedule (shared with the sim's per-message
        scheduling).  Color class c is a matching, so sending BOTH
        directions of each of its edges is still a valid (partial)
        permutation; workers without a color-c edge receive ppermute's
        zero fill."""
        return edge_schedule(self.topo)

    def _make_exchange(self, sharded: bool):
        """payload pytree of (W, ...) arrays -> tuple over ports.

        result[c][w] = payload[partner of w in edge color c] (zeros where w
        has no color-c edge).  The sharded path sends each device's shard to
        the matching device of the partner worker group with
        jax.lax.ppermute — uint8 payloads stay uint8 on the wire, and with
        pack_wire each device nibble-packs its own shard right before the
        ppermute and unpacks right after (pack4/unpack4 run as purely local
        ops inside the shard_map: halved wire bytes, and no SPMD
        partitioning of the strided pack pattern, which XLA:CPU
        miscompiles).
        """
        w = self.dcfg.num_workers
        topo = self.topo
        ports = topo.num_ports
        if not sharded:
            # Unsharded reference: gather by the partner table; packing
            # would be an exact roundtrip (contract-tested in
            # tests/test_kernels.py), so the levels move unpacked.
            partner = topo.port  # (W, C) int, -1 where no edge
            idxs = [jnp.asarray(np.where(partner[:, c] >= 0, partner[:, c],
                                         np.arange(w)))
                    for c in range(ports)]
            masks = [jnp.asarray(partner[:, c] >= 0) for c in range(ports)]

            def exchange(payload):
                outs = []
                for c in range(ports):
                    idx, m = idxs[c], masks[c]
                    outs.append(jax.tree.map(
                        lambda x: jnp.where(
                            _bmask(m, x), jnp.take(x, idx, axis=0),
                            jnp.zeros_like(x)), payload))
                return tuple(outs)
            return exchange

        mesh = self.mesh
        perms = self._port_perms()
        pack_impl = self._pack_impl()
        wire_spec = P("worker", ("fsdp", "model"))

        def spec_of(a):
            if a.ndim == 2 and a.shape[1] % self._group_size() == 0:
                return wire_spec
            return P("worker", *(None,) * (a.ndim - 1))

        def exchange(payload):
            specs = jax.tree.map(spec_of, payload)
            # which leaves get per-shard nibble packing (bool leaves: a
            # PartitionSpec is a tuple subclass, so specs can't be mapped
            # over as a second operand tree)
            packed_leaves = jax.tree.map(
                lambda x: bool(self.dcfg.pack_wire and x.dtype == jnp.uint8
                               and spec_of(x) == wire_spec), payload)

            def body(p):
                def send(x, do_pack, perm):
                    if do_pack:
                        n_loc = x.size  # local (1, D_pad / group) shard
                        packed = pack_ops.pack4(x.reshape(-1),
                                                impl=pack_impl)
                        recv = jax.lax.ppermute(packed, "worker", perm)
                        return pack_ops.unpack4(
                            recv, n_loc, impl=pack_impl).reshape(x.shape)
                    return jax.lax.ppermute(x, "worker", perm)

                return tuple(
                    jax.tree.map(lambda x, f: send(x, f, perm),
                                 p, packed_leaves)
                    for perm in perms)

            return shard_map(body, mesh=mesh, in_specs=(specs,),
                             out_specs=(specs,) * ports,
                             check_rep=False)(payload)

        return exchange

    # ------------------------------------------------------- quantization --
    def _per_leaf_radius(self, leaves, hat_leaves):
        """(W, L) per-leaf inf-norm radii; zero-size leaves get R = 0 (the
        same guard quantizer.global_radius applies)."""
        w = self.dcfg.num_workers
        cols = []
        for x, h in zip(leaves, hat_leaves):
            if int(np.prod(x.shape[1:])) == 0:
                cols.append(jnp.zeros((w,), jnp.float32))
            else:
                cols.append(jax.vmap(lambda a, b: jnp.max(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32))))(x, h))
        if not cols:
            return jnp.zeros((w, 0), jnp.float32)
        return jnp.stack(cols, axis=1)

    def _per_leaf_delta2(self, a_leaves, b_leaves):
        """(W, L) per-leaf squared L2 distances — the residual-magnitude
        ranking score of the bit-budget controller and the per-leaf censor
        statistic (zero-size leaves get 0)."""
        w = self.dcfg.num_workers
        cols = []
        for x, h in zip(a_leaves, b_leaves):
            if int(np.prod(x.shape[1:])) == 0:
                cols.append(jnp.zeros((w,), jnp.float32))
            else:
                d = (x.astype(jnp.float32)
                     - h.astype(jnp.float32)).reshape(w, -1)
                cols.append(jnp.sum(d * d, axis=1))
        if not cols:
            return jnp.zeros((w, 0), jnp.float32)
        return jnp.stack(cols, axis=1)

    def _qdq_row(self, theta_row, hat_row, u_row, radius, bits):
        """One fused quantize-dequantize call on one (d,) wire-row slab.
        radius is a scalar (global mode) or a (d,) per-element expansion
        (per_tensor mode); bits is a scalar or a (d,) per-element expansion
        (layerwise per-leaf widths)."""
        levels = (2.0 ** bits.astype(jnp.float32)) - 1.0
        radius = jnp.asarray(radius, jnp.float32)
        if self.dcfg.wire_impl == "jnp":
            return q_ref.quantize_dequantize_ref(
                theta_row, hat_row, u_row, radius, levels)
        return q_kernel.quantize_dequantize(
            theta_row, hat_row, u_row, radius, levels,
            interpret=self.dcfg.wire_impl != "pallas_compiled")

    def _qdq_sharded(self, theta_f, hat_f, u, radius, bits, seg=None):
        """Codec under shard_map: every device runs one fused
        quantize-dequantize on exactly the (1, d_loc) wire slab it owns,
        with its worker's radius/bits riding along the 'worker' axis.

        This keeps the codec internals out of the SPMD partitioner — which
        XLA:CPU miscompiles for the pad/reshape/slice patterns inside the
        kernels (same bug family as the RoPE note in dist.sharding) — and
        is the production TPU layout anyway: local data, local kernel.

        Per-leaf radius/bits (ndim == 2) arrive as the raw (W, L) tables
        plus the static position->leaf map `seg` and expand to per-position
        values INSIDE the body, on each device's own slab.  Expanding
        outside (the old `per_leaf_r[:, seg]` form) hands the partitioner
        a gather whose output is sharded along the gathered dimension,
        which XLA:CPU miscompiles inside the fused step — the sender
        quantized against garbage radii while receivers (whose decode runs
        on replicated operands, see phase_apply) used the true ones, so
        every sharded per_tensor/layerwise run silently desynced and the
        consensus residual grew without bound."""
        wspec = P("worker") if self.dcfg.num_workers > 1 else P(None)
        bspec = P(*wspec, ("fsdp", "model"))
        lspec = P(*wspec, None)
        rspec = lspec if radius.ndim == 2 else wspec
        bitspec = lspec if bits.ndim == 2 else wspec
        d_pad = theta_f.shape[1]
        if seg is not None:
            # padding positions -> sentinel leaf L: R = 0 keeps them inert,
            # b = 1 keeps the codec's levels >= 1
            n_leaves = int(radius.shape[1] if radius.ndim == 2
                           else bits.shape[1])
            seg_pad = np.full((d_pad,), n_leaves, np.int32)
            seg_pad[:seg.size] = seg
        msize = self.mesh.shape["model"]

        def body(th, h, uu, rr, bb):
            rr_row, bb_row = rr[0], bb[0]
            if seg is not None:
                d_loc = th.shape[1]
                slab = (jax.lax.axis_index("fsdp") * msize
                        + jax.lax.axis_index("model"))
                seg_loc = jax.lax.dynamic_slice(
                    jnp.asarray(seg_pad), (slab * d_loc,), (d_loc,))
                if rr.ndim == 2:
                    rr_row = jnp.concatenate(
                        [rr_row, jnp.zeros((1,), rr.dtype)])[seg_loc]
                if bb.ndim == 2:
                    bb_row = jnp.concatenate(
                        [bb_row, jnp.ones((1,), bb.dtype)])[seg_loc]
            q, hh = self._qdq_row(th[0], h[0], uu[0], rr_row, bb_row)
            return q[None], hh[None]

        return shard_map(
            body, mesh=self.mesh,
            in_specs=(bspec, bspec, bspec, rspec, bitspec),
            out_specs=(bspec, bspec), check_rep=False)(
                theta_f, hat_f, u, radius, bits)

    def _quantize_all(self, theta, hat, bits_prev, radius_prev, key,
                      sharded: bool, step_idx=None):
        """Quantize every worker row on the flat wire buffer.

        Returns (q_wire (W, D_pad) uint8, hat_new pytree, r_new, b_new,
        leaf_due) with r_new (W,) in global mode / (W, L) per_tensor.  Bit
        adaptation (paper eq. 11) tracks the global radius ratio — or, in
        layerwise mode, each leaf's own ratio, unless the bit-budget
        controller (quantizer.allocate_bits) supersedes it.  leaf_due is
        the (W, L) exchange-period gate in layerwise mode (None otherwise);
        the codec itself always runs on every leaf with the full fresh
        radii, so the shared uniform draw is consumed identically whatever
        the masks — callers zero the PAYLOAD radius of unsent leaves
        instead, which no-ops them on both endpoints.

        Shared uniform-draw convention: ONE jax.random.uniform draw over the
        padded (W, D_pad) buffer, consumed identically by every wire_impl —
        the jnp and Pallas paths are bit-identical.
        """
        qcfg = self.dcfg.gadmm.qcfg
        lw = self.dcfg.layerwise
        w = self.dcfg.num_workers
        leaves = jax.tree.leaves(theta)
        treedef = jax.tree.structure(theta)
        hat_leaves = treedef.flatten_up_to(hat)
        sizes = _leaf_sizes(leaves)
        n_leaves = len(sizes)
        per_leaf_r = self._per_leaf_radius(leaves, hat_leaves)  # (W, L)
        r_global = (jnp.max(per_leaf_r, axis=1) if per_leaf_r.shape[1]
                    else jnp.zeros((w,), jnp.float32))
        leaf_due = None
        if lw is not None:
            base_b, periods, _ = self._lw_tables(tuple(sizes))
            if lw.budget_bits is not None:
                # budget controller: rank leaves by residual magnitude,
                # spend the fixed wire budget best-first
                scores = jnp.sqrt(self._per_leaf_delta2(leaves, hat_leaves))
                b_new = allocate_bits(scores, np.asarray(sizes, np.float32),
                                      lw.budget_bits, lw.min_bits,
                                      lw.max_bits)              # (W, L)
            elif lw.adapt_bits:
                # eq. 11 per leaf: each leaf tracks its own radius ratio
                b_new = _next_bits(self._lw_qcfg, bits_prev, per_leaf_r,
                                   radius_prev, base_bits=base_b[None])
            else:
                b_new = jnp.broadcast_to(base_b[None], (w, n_leaves))
            leaf_due = jnp.broadcast_to((step_idx % periods) == 0,
                                        (w, n_leaves))
            r_new = per_leaf_r
        elif qcfg.adapt_bits:
            r_prev = (radius_prev if radius_prev.ndim == 1
                      else jnp.max(radius_prev, axis=1))
            b_new = _next_bits(qcfg, bits_prev, r_global, r_prev)  # (W,)
        else:
            b_new = jnp.full((w,), qcfg.bits, jnp.int32)
        if lw is None:
            r_new = (r_global if self.dcfg.radius_mode == "global"
                     else per_leaf_r)

        d = sum(sizes)
        if d == 0:
            return (jnp.zeros((w, 0), jnp.uint8),
                    jax.tree.unflatten(treedef, list(hat_leaves)),
                    r_new, b_new, leaf_due)
        theta_f = self._pad_wire(self._flatten_rows(leaves, jnp.float32))
        hat_f = self._pad_wire(self._flatten_rows(hat_leaves, jnp.float32))
        d_pad = theta_f.shape[1]
        u = jax.random.uniform(key, (w, d_pad), jnp.float32)
        per_tensor = self.dcfg.radius_mode == "per_tensor"
        seg = (np.repeat(np.arange(n_leaves), sizes)           # (D,)
               if (per_tensor or lw is not None) else None)
        if sharded:
            # per-leaf (W, L) tables ride into the shard_map untouched and
            # expand to per-position values on each device's local slab —
            # the outside-expansion form below is a gather the SPMD
            # partitioner must shard along the gathered dimension, which
            # XLA:CPU miscompiles (see _qdq_sharded)
            q_wire, hat_new_f = self._qdq_sharded(
                theta_f, hat_f, u,
                per_leaf_r if per_tensor else r_global,
                b_new, seg=seg)
        else:
            if per_tensor:
                # segment-scalar pass: per-leaf scalars -> per-position
                # values; padding positions get R = 0 (codec leaves them
                # untouched)
                r_arg = self._pad_wire(per_leaf_r[:, seg])     # (W, D_pad)
            else:
                r_arg = r_global
            b_arg = b_new
            if lw is not None:
                # per-position bit widths; padding gets b = 1 (levels >= 1
                # — the codec divides by levels; R = 0 keeps them inert)
                b_pos = b_new[:, seg]
                if d_pad > d:
                    b_pos = jnp.pad(b_pos, ((0, 0), (0, d_pad - d)),
                                    constant_values=1)
                b_arg = b_pos                                  # (W, D_pad)
            q_rows, hat_rows = [], []
            for i in range(w):
                q_i, h_i = self._qdq_row(theta_f[i], hat_f[i], u[i],
                                         r_arg[i], b_arg[i])
                q_rows.append(q_i)
                hat_rows.append(h_i)
            q_wire = jnp.stack(q_rows)                 # (W, D_pad) uint8
            hat_new_f = jnp.stack(hat_rows)            # (W, D_pad) f32
        hat_new = self._unflatten_cast(hat_new_f, hat_leaves, treedef)
        return q_wire, hat_new, r_new, b_new, leaf_due

    def _dequantize_all(self, q_wire, hat_copy, radius, bits):
        """Receiver-side reconstruction on the flat wire buffer against the
        stored neighbor hats — identical f32 arithmetic (and per-leaf final
        cast) to the sender's fused kernel, preserving bit-sync."""
        treedef = jax.tree.structure(hat_copy)
        hat_leaves = treedef.flatten_up_to(hat_copy)
        hat_f = self._flatten_rows(hat_leaves, jnp.float32)    # (W, D)
        if hat_f.shape[1] == 0:
            return hat_copy
        sizes = _leaf_sizes(hat_leaves)
        seg = np.repeat(np.arange(len(sizes)), sizes)
        if bits.ndim == 1:
            levels = ((2.0 ** bits.astype(jnp.float32)) - 1.0)[:, None]
        else:
            # layerwise per-leaf widths -> per-position levels
            levels = (2.0 ** bits[:, seg].astype(jnp.float32)) - 1.0
        r_pos = radius[:, None] if radius.ndim == 1 else radius[:, seg]
        safe_r = jnp.maximum(r_pos, 1e-30)
        step = 2.0 * safe_r / levels
        out = hat_f + step * q_wire.astype(jnp.float32) - r_pos
        out = jnp.where(r_pos > 0, out, hat_f)
        return self._unflatten_cast(out, hat_leaves, treedef)

    # ------------------------------------------------------------- step ----
    def _data_loss(self, theta_w, batch_w):
        mb = self.dcfg.microbatches
        if mb <= 1:
            return self.model.loss_fn(theta_w, batch_w, self.cfg)
        split = jax.tree.map(
            lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]), batch_w)

        def body(acc, b):
            return acc + self.model.loss_fn(theta_w, b, self.cfg), None

        total, _ = jax.lax.scan(body, jnp.zeros(()), split)
        return total / mb

    def _local_loss(self, theta_w, batch_w, lam_nbr, hat_nbr, pmask, sign):
        """Stochastic augmented Lagrangian of eq. 14/16 for one worker.

        lam_nbr / hat_nbr: per-port tuples of this worker's edge duals and
        neighbor-hat reconstructions; pmask[c] = 1.0 iff the worker has a
        color-c edge; sign = +1 for heads, -1 for tails (the edge dual's
        canonical orientation is head -> tail, so the head sees
        <lam, theta - hat_nbr> and the tail <lam, hat_nbr - theta>)."""
        rho = self.dcfg.gadmm.rho
        f = self._data_loss(theta_w, batch_w)
        dual = jnp.zeros(())
        prox = jnp.zeros(())
        for c in range(len(hat_nbr)):
            diff = jax.tree.map(jnp.subtract, theta_w, hat_nbr[c])
            dual = dual + pmask[c] * sign * _tvdot(lam_nbr[c], diff)
            prox = prox + pmask[c] * _tsqnorm(theta_w, hat_nbr[c])
        return f + dual + 0.5 * rho * prox, f

    def _local_opt(self, theta, mu, nu, t, batch_w, lam_nbr, hat_nbr,
                   pmask, sign):
        """local_iters Adam steps on the augmented Lagrangian (one worker)."""
        lr = self.dcfg.local_lr
        grad_fn = jax.value_and_grad(self._local_loss, has_aux=True)

        def body(carry, _):
            th, m, v, tt = carry
            (_, f), g = grad_fn(th, batch_w, lam_nbr, hat_nbr, pmask, sign)
            tt = tt + 1
            tf = tt.astype(jnp.float32)
            m = jax.tree.map(
                lambda mm, gg: _ADAM_B1 * mm + (1 - _ADAM_B1) * gg, m, g)
            v = jax.tree.map(
                lambda vv, gg: _ADAM_B2 * vv + (1 - _ADAM_B2) * gg * gg, v, g)
            th = jax.tree.map(
                lambda t_, mm, vv: (t_ - lr * (mm / (1 - _ADAM_B1 ** tf))
                                    / (jnp.sqrt(vv / (1 - _ADAM_B2 ** tf))
                                       + _ADAM_EPS)).astype(t_.dtype),
                th, m, v)
            return (th, m, v, tt), f

        (theta, mu, nu, t), fs = jax.lax.scan(
            body, (theta, mu, nu, t), None, length=self.dcfg.local_iters)
        return theta, mu, nu, t, fs[0]

    def make_train_step(self):
        """Unsharded (single-process) reference step: identical math to the
        sharded step, neighbor exchange via array shifts instead of ppermute."""
        return self._build_step(sharded=False)

    def jit_train_step(self, state: DistState, batch):
        """Jitted sharded step; state/batch may be arrays or ShapeDtypeStructs
        (AOT lowering for dry runs)."""
        ss = self._shardings(self.state_specs(state))
        bs = self._shardings(self.batch_specs(batch))
        return jax.jit(self._build_step(sharded=True),
                       in_shardings=(ss, bs), out_shardings=(ss, None))

    def phase_compute(self, st, batch, active, key, step_idx,
                      sharded: bool = False, port_weights=None):
        """Local Adam + quantize (+ censor) for the active workers;
        returns the updated state and the wire payload (exchange NOT yet
        applied).  payload['sent'] is the per-worker transmit flag — the
        1-bit censor sideband that rides every link.  In layerwise mode
        payload['leaf_sent'] is the effective (W, L) per-leaf transmit
        mask (accounting only — receivers need nothing beyond the
        leaf-masked radius sideband; _build_step pops it before the
        exchange).

        `port_weights` (W, C) overrides the 0/1 port mask weighting the
        neighbor dual/prox terms of the local loss — partial
        participation passes degree-renormalized weights that drop
        absent neighbors' frozen hats (None = self.pmask, the full
        topology).

        Worker row w of every output depends only on row w of the inputs
        (plus the shared uniform-draw key), so a single worker can replay
        its own row from a local view whose other rows are garbage — the
        contract repro.sim.worker.TrainerActor builds on."""
        g = self.dcfg.gadmm
        cc = self.dcfg.censor
        w = self.dcfg.num_workers
        pw = self.pmask if port_weights is None else port_weights
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        # project the edge slabs to the per-(worker, color) port views the
        # per-worker local loss is written against (exact; see _port_view)
        hat_nbr = self._port_view(hat_edge)
        lam_nbr = self._port_view(lam_edge)
        new_theta, new_mu, new_nu, new_t, f0 = jax.vmap(self._local_opt)(
            theta, mu, nu, t, batch, lam_nbr, hat_nbr, pw, self.sign)
        theta = _twhere(active, new_theta, theta)
        mu = _twhere(active, new_mu, mu)
        nu = _twhere(active, new_nu, nu)
        t = jnp.where(active, new_t, t)

        if g.quantize:
            q_wire, hat_new, r_new, b_new, leaf_due = self._quantize_all(
                theta, hat, bits, radius, key, sharded, step_idx)
            lw = self.dcfg.layerwise
            if lw is not None:
                # L-FGADMM leaf gating: a leaf is transmitted only on its
                # period rounds, and (with per-leaf taus) only when its
                # committed quantized delta moved past the decaying
                # threshold.  The candidate hat is the per-leaf mix of
                # new/old — what would actually be committed — so the
                # worker-level censor below sees the true delta and both
                # endpoints stay bit-synced (unsent leaves ride the payload
                # with radius 0, a codec no-op for every receiver).
                treedef = jax.tree.structure(hat)
                hn = treedef.flatten_up_to(hat_new)
                ho = treedef.flatten_up_to(hat)
                leaf_sent = leaf_due
                _, _, taus = self._lw_tables(
                    tuple(_leaf_sizes(jax.tree.leaves(theta))))
                if taus is not None:
                    thr = taus * jnp.power(
                        jnp.float32(lw.tau_xi),
                        jnp.asarray(step_idx, jnp.float32))    # (L,)
                    delta = jnp.sqrt(self._per_leaf_delta2(hn, ho))
                    leaf_sent = leaf_sent & (delta > thr)
                hat_cand = jax.tree.unflatten(treedef, [
                    jnp.where(_bmask(leaf_sent[:, i], a), a, b)
                    for i, (a, b) in enumerate(zip(hn, ho))])
                if cc is not None:
                    sent = active & censor_mod.transmit_mask(
                        hat_cand, hat, cc, step_idx)
                else:
                    sent = active
                eff_leaf = leaf_sent & sent[:, None]           # (W, L)
                hat = _twhere(sent, hat_cand, hat)
                radius = jnp.where(eff_leaf, r_new, radius)
                bits = jnp.where(eff_leaf, b_new, bits)
                payload = {"wire": self._finish_wire(q_wire),
                           "radius": jnp.where(eff_leaf, r_new, 0.0),
                           "bits": b_new, "sent": sent,
                           "leaf_sent": eff_leaf}
            else:
                if cc is not None:
                    # CQ-GGADMM censoring: commit + transmit only when the
                    # quantized model moved past the decaying threshold.
                    # hat_new is the committed (per-leaf-cast) value, so the
                    # mask is identical for every wire_impl and on both the
                    # unsharded and sharded paths.
                    sent = active & censor_mod.transmit_mask(
                        hat_new, hat, cc, step_idx)
                else:
                    sent = active
                hat = _twhere(sent, hat_new, hat)
                radius = jnp.where(_bmask(sent, r_new), r_new, radius)
                bits = jnp.where(sent, b_new, bits)
                payload = {"wire": self._finish_wire(q_wire),
                           "radius": r_new, "bits": b_new, "sent": sent}
        else:
            # full-precision GADMM: track the would-be radius for metrics,
            # then "transmit" theta itself (hat == theta).  Censoring
            # applies identically (this is C-GGADMM).
            per_leaf_r = self._per_leaf_radius(
                jax.tree.leaves(theta), jax.tree.leaves(hat))  # (W, L)
            if cc is not None:
                sent = active & censor_mod.transmit_mask(
                    theta, hat, cc, step_idx)
            else:
                sent = active
            hat = _twhere(sent, theta, hat)
            r_new = (jnp.max(per_leaf_r, axis=1)
                     if radius.ndim == 1 and per_leaf_r.shape[1]
                     else (per_leaf_r if radius.ndim > 1
                           else jnp.zeros((w,), jnp.float32)))
            radius = jnp.where(_bmask(sent, r_new), r_new, radius)
            payload = {"wire": self._flatten_wire(
                jax.tree.leaves(hat), jnp.float32), "sent": sent}

        return (theta, hat, hat_edge, lam_edge, radius, bits,
                mu, nu, t), payload, f0

    def phase_apply(self, st, recv, sharded: bool = False):
        """Fold the exchanged payloads into the edge-indexed neighbor hats.

        recv[c]['sent'][w] is the exchanged censor flag: did w's color-c
        partner transmit?  Censored (or phase-inactive) partners leave
        the stored hat untouched — exactly what their own rolled-back
        state holds, preserving bit-sync.  Directed row d is served by
        the payload worker dst[d] received on port color[d], so the whole
        slab commits as ONE uniform gather + decode + where over the 2E
        rows — one decode per directed edge, O(E) work instead of the
        port-dense O(W*C).

        The full-slab form is deliberate: an earlier per-color version
        (static row-subset gather, decode, ``.at[rows].set`` scatter)
        was miscompiled by XLA:CPU's SPMD partitioner inside the fused
        sharded step — O(1) absolute garbage in the committed rows once
        the slab was nonzero (same bug family as the RoPE and
        in-shard-codec notes; sharding pins on the operands did NOT fix
        the fused program).  The uniform gather/where form avoids the
        scatter entirely.  sharded=True additionally pins the decode's
        operands replicated — the slabs are O(E*D) and every worker
        stores them anyway, so that is the intended semantics, not a
        workaround cost."""
        g = self.dcfg.gadmm
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        if self.eidx.num_directed == 0:
            return st
        if sharded:
            recv, hat_edge = self._replicate((recv, hat_edge))
        col, dst = self._d_color, self._d_dst

        def pick(k):
            # (C, W, ...) stacked payloads -> per-directed-row (2E, ...)
            return jnp.stack([r[k] for r in recv])[col, dst]

        got = pick("sent")
        wire = pick("wire")
        if g.quantize:
            d = sum(_leaf_sizes(jax.tree.leaves(theta)))
            dec = self._dequantize_all(self._strip_wire(wire, d), hat_edge,
                                       pick("radius"), pick("bits"))
        else:
            treedef = jax.tree.structure(hat_edge)
            leaves = treedef.flatten_up_to(hat_edge)
            ls = self._unflatten_wire(wire, leaves)
            dec = jax.tree.unflatten(
                treedef, [l.astype(r.dtype) for l, r in zip(ls, leaves)])
        hat_edge = _twhere(got, dec, hat_edge)
        return (theta, hat, hat_edge, lam_edge, radius, bits,
                mu, nu, t)

    def dual_update(self, st, edge_mask=None, sharded: bool = False):
        """Damped dual update (eq. 18) from reconstructed hats; both ends
        of each edge apply the same increment, keeping duals in sync:
        lam_e += a*rho*(hat_head - hat_tail), which the head computes
        as +(own - nbr) and the tail as -(own - nbr) — per directed edge
        d that is sign_dst[d] * (hat[dst[d]] - hat_edge[d]).

        `edge_mask` (2E,) zeroes selected directed edges — the simulator
        masks edges whose far endpoint dropped (freezing those duals
        instead of integrating a stale residual forever), the staleness
        pipeline masks everything during fill rounds.

        sharded=True pins the worker-stacked hats replicated before the
        (2E,)-row gather: leaving the gather on the worker-sharded
        layout makes XLA:CPU's SPMD partitioner corrupt OTHER values in
        the fused step (the committed hat_edge rows — the gather's mere
        presence flips the partitioning of the decode upstream)."""
        g = self.dcfg.gadmm
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        if self.eidx.num_directed == 0:
            return st
        coef = (self._d_sign if edge_mask is None
                else self._d_sign * edge_mask)   # (2E,) f32
        scale = g.alpha * g.rho
        g_hat = self._replicate(hat) if sharded else hat
        own = jax.tree.map(lambda a: a[self._d_dst], g_hat)
        lam_edge = jax.tree.map(
            lambda l, a, b: l + scale * _bmask(coef, l).astype(l.dtype)
            * (a.astype(l.dtype) - b.astype(l.dtype)),
            lam_edge, own, hat_edge)
        return (theta, hat, hat_edge, lam_edge, radius, bits,
                mu, nu, t)

    def _build_step(self, sharded: bool):
        dcfg = self.dcfg
        g = dcfg.gadmm
        cc = dcfg.censor
        w = dcfg.num_workers
        topo = self.topo
        ports = topo.num_ports
        if sharded and "worker" in self.mesh.shape:
            assert self.mesh.shape["worker"] == w, (
                f"mesh worker axis {self.mesh.shape['worker']} != "
                f"num_workers {w}")
        is_head = self.is_head
        port_on = self.port_on
        all_on = jnp.ones((w,), bool)
        exchange = (self._make_exchange(sharded) if topo.num_edges else None)
        phase_compute = functools.partial(self.phase_compute, sharded=sharded)
        phase_apply = functools.partial(self.phase_apply, sharded=sharded)
        dual_update = functools.partial(self.dual_update, sharded=sharded)

        port_idx = jnp.asarray(topo.port, jnp.int32) if ports else None

        def participation_masks(round_key):
            """Per-round shared-knowledge participation draw: (W,) bool
            mask, degree-renormalized (W, C) port weights, and the (2E,)
            both-endpoints edge gate.  Derived by fold_in from the round
            key (NOT by splitting it) so the participation=1.0 key
            stream — and every committed golden — is untouched."""
            part = jax.random.bernoulli(
                jax.random.fold_in(round_key, 0x9A77), dcfg.participation,
                (w,))
            if port_idx is None:
                return part, self.pmask, None
            nbr_part = (part[jnp.maximum(port_idx, 0)].astype(jnp.float32)
                        * self.pmask)                          # (W, C)
            deg = jnp.sum(self.pmask, axis=1)
            present = jnp.sum(nbr_part, axis=1)
            pw = nbr_part * (deg / jnp.maximum(present, 1.0))[:, None]
            edge_part = None
            if self.eidx.num_directed:
                edge_part = (part[self._d_src]
                             & part[self._d_dst]).astype(jnp.float32)
            return part, pw, edge_part

        def step(state: DistState, batch):
            key, k1, k2 = jax.random.split(state.key, 3)
            st = (state.theta, state.theta_hat, state.hat_edge,
                  state.lam_edge, state.radius, state.bits, state.opt_mu,
                  state.opt_nu, state.opt_t)
            sent_phases = []
            leaf_phases = []   # layerwise: (eff_leaf, bits) per phase
            inbox, hat_lag = state.inbox, state.hat_lag
            part = pw = edge_part = None
            if dcfg.participation < 1.0:
                part, pw, edge_part = participation_masks(state.key)
            mask = (lambda a: a) if part is None else (lambda a: a & part)

            def phase(st, active, k):
                st, payload, f0 = phase_compute(st, batch, mask(active), k,
                                                state.step, port_weights=pw)
                sent_phases.append(payload["sent"])
                lf = payload.pop("leaf_sent", None)
                if lf is not None:
                    leaf_phases.append((lf, payload["bits"]))
                if exchange is not None:
                    st = phase_apply(st, exchange(payload))
                return st, f0

            stale = (dcfg.staleness > 0 and w > 1 and topo.num_edges > 0)
            if stale:
                # pipelined exchange: decode the round-(k-S) inbox entry
                # (recv-done), run BOTH phases against those S-stale hats,
                # dual-update on matching S-stale snapshots, then push this
                # round's merged payload into the in-flight ring (send /
                # recv-start).  Wire bits are billed below on THIS round —
                # the round the payload is sent — never on the round it is
                # eventually consumed.
                (st, hat_lag, f0, sent_phases, leaf_phases,
                 inbox) = self._stale_round(
                    st, batch, state, hat_lag, k1, k2, sharded,
                    part=part, port_weights=pw, edge_part=edge_part)
            elif dcfg.mode == "gauss-seidel" and w > 1 and dcfg.overlap:
                # double-buffered exchange: put the heads' payload on the
                # wire, run the tails' local iterations against the PREVIOUS
                # neighbor hats while it is in flight, then fold both
                # exchanges in.  XLA sees no data dependence between the
                # heads' ppermute and the tails' compute, so the graph
                # latency hides behind the Adam iterations.
                st, pl_h, f0 = phase_compute(st, batch, mask(is_head), k1,
                                             state.step, port_weights=pw)
                sent_phases.append(pl_h["sent"])
                lf = pl_h.pop("leaf_sent", None)
                if lf is not None:
                    leaf_phases.append((lf, pl_h["bits"]))
                recv_h = exchange(pl_h)
                st, pl_t, _ = phase_compute(st, batch, mask(~is_head), k2,
                                            state.step, port_weights=pw)
                sent_phases.append(pl_t["sent"])
                lf = pl_t.pop("leaf_sent", None)
                if lf is not None:
                    leaf_phases.append((lf, pl_t["bits"]))
                st = phase_apply(st, recv_h)
                st = phase_apply(st, exchange(pl_t))
                st = dual_update(st, edge_mask=edge_part)
            elif dcfg.mode == "gauss-seidel" and w > 1:
                st, f0 = phase(st, is_head, k1)
                st, _ = phase(st, ~is_head, k2)
                st = dual_update(st, edge_mask=edge_part)
            else:
                st, f0 = phase(st, all_on, k1)
                st = dual_update(st, edge_mask=edge_part)
            (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st

            # consensus violation, each edge counted once (from its head:
            # directed rows whose dst is the head endpoint); gather from a
            # replicated view — see dual_update's sharded note
            resid_sq = jnp.zeros(())
            if self.eidx.num_directed:
                m = self._d_sign > 0
                g_hat = self._replicate(hat) if sharded else hat
                own = jax.tree.map(lambda a: a[self._d_dst], g_hat)
                resid_sq = resid_sq + sum(jax.tree.leaves(jax.tree.map(
                    lambda a, b: jnp.sum(_bmask(m, a)
                                         * (a.astype(jnp.float32)
                                            - b.astype(jnp.float32)) ** 2),
                    own, hat_edge)))
            sent_total = sum(jnp.sum(s.astype(jnp.float32))
                             for s in sent_phases)
            metrics = {
                "loss": jnp.mean(f0),
                "consensus_resid": jnp.sqrt(resid_sq),
                "radius_mean": jnp.mean(radius),
                "bits_mean": jnp.mean(bits.astype(jnp.float32)),
                # every worker is transmit-eligible exactly once per round
                "skip_rate": 1.0 - sent_total / w,
                "wire_bits_per_round": jnp.asarray(
                    self.wire_bits_per_round(
                        theta,
                        sent_phases
                        if (cc is not None or dcfg.participation < 1.0)
                        else None,
                        leaf_phases if dcfg.layerwise is not None else None),
                    jnp.float32),
            }
            if dcfg.telemetry:
                sp = (sent_phases
                      if (cc is not None or dcfg.participation < 1.0)
                      else None)
                lp = leaf_phases if dcfg.layerwise is not None else None
                pay, hdr, flg = self.wire_bits_components(theta, sp, lp)
                deg = jnp.asarray(topo.degree, jnp.float32)
                sent_any = (sum(s.astype(jnp.float32) for s in sent_phases)
                            if sent_phases else jnp.zeros((w,), jnp.float32))
                dual_sq = jnp.zeros(())
                if self.eidx.num_directed:
                    hm = self._d_sign > 0
                    dual_sq = dual_sq + sum(jax.tree.leaves(jax.tree.map(
                        lambda a, b: jnp.sum(
                            _bmask(hm, a)
                            * (a.astype(jnp.float32)
                               - b.astype(jnp.float32)) ** 2),
                        lam_edge, state.lam_edge)))
                metrics.update({
                    "wire_bits_payload": jnp.asarray(pay, jnp.float32),
                    "wire_bits_header": jnp.asarray(hdr, jnp.float32),
                    "wire_bits_flags": jnp.asarray(flg, jnp.float32),
                    # directed links that carried payload / stayed silent
                    "tx_links": jnp.asarray(
                        sum(jnp.sum(s.astype(jnp.float32) * deg)
                            for s in sent_phases), jnp.float32),
                    "skip_links": jnp.sum((1.0 - sent_any) * deg),
                    # (W,) per-worker transmit mask: per-edge censor skip
                    # counts expand host-side via the static edge index
                    "worker_sent": sent_any,
                    "dual_resid": jnp.sqrt(dual_sq),
                    "participants": (jnp.sum(part.astype(jnp.float32))
                                     if part is not None
                                     else jnp.asarray(float(w),
                                                      jnp.float32)),
                })
                if dcfg.layerwise is not None:
                    # (L,) mean allocated bits per leaf across workers
                    metrics["leaf_bits"] = jnp.mean(
                        bits.astype(jnp.float32), axis=0)
            new_state = DistState(
                theta=theta, theta_hat=hat, hat_edge=hat_edge,
                lam_edge=lam_edge, radius=radius, bits=bits,
                opt_mu=mu, opt_nu=nu, opt_t=t, key=key, step=state.step + 1,
                inbox=inbox, hat_lag=hat_lag)
            return new_state, metrics

        return step

    # ------------------------------------------------- staleness pipeline --
    def _decode_rows(self, wire, prev, radius, bits):
        """Decode stripped wire rows against stored prev rows — the shared
        recv-done arithmetic for neighbor slab rows and the own-hat lag
        (identical to the barriered path's _dequantize_all, so a staleness
        pipeline replays the exact bytes the S=0 exchange would)."""
        if self.dcfg.gadmm.quantize:
            return self._dequantize_all(wire, prev, radius, bits)
        treedef = jax.tree.structure(prev)
        leaves = treedef.flatten_up_to(prev)
        ls = self._unflatten_wire(wire, leaves)
        return jax.tree.unflatten(
            treedef, [l.astype(r.dtype) for l, r in zip(ls, leaves)])

    def _stale_round(self, st, batch, state: DistState, hat_lag, k1, k2,
                     sharded: bool, part=None, port_weights=None,
                     edge_part=None):
        """One staleness-S round: recv-done on the oldest inbox entry, both
        compute phases against the S-stale hats, fresh-edge-gated dual
        update on matching S-stale snapshots, send into the ring.  With
        partial participation the round's shared mask gates the compute
        phases (`part`), reweights the neighbor sums (`port_weights`) and
        joins the fresh-edge gate on the dual (`edge_part`) — absent
        workers push a sent=False entry into the ring, so their slot is
        silent when it reaches recv-done S rounds later."""
        dcfg = self.dcfg
        s_depth = dcfg.staleness
        phase_compute = functools.partial(self.phase_compute, sharded=sharded,
                                          port_weights=port_weights)

        # ---- recv-done: decode the round-(k-S) entry -----------------
        entry = jax.tree.map(lambda a: a[0], state.inbox)
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        if sharded:
            # same SPMD-partitioner pin as phase_apply(sharded=True)
            entry, hat_edge, hat_lag = self._replicate(
                (entry, hat_edge, hat_lag))
        sent_e = entry["sent"][self._d_src]                    # (2E,)
        dec_e = self._decode_rows(
            entry["wire"][self._d_src], hat_edge,
            entry["radius"][self._d_src], entry["bits"][self._d_src])
        hat_edge = _twhere(sent_e, dec_e, hat_edge)
        # own-hat snapshot, decoded from the SAME payload stream the
        # neighbors decode — hat_lag[w] stays bitwise-equal to every
        # hat_edge row with src=w, so dual mirrors cannot drift
        dec_lag = self._decode_rows(entry["wire"], hat_lag,
                                    entry["radius"], entry["bits"])
        hat_lag = _twhere(entry["sent"], dec_lag, hat_lag)
        st = (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t)

        # ---- compute: both phases against the S-stale hats -----------
        act_h = self.is_head if part is None else self.is_head & part
        act_t = ~self.is_head if part is None else ~self.is_head & part
        st, pl_h, f0 = phase_compute(st, batch, act_h, k1, state.step)
        st, pl_t, _ = phase_compute(st, batch, act_t, k2, state.step)
        sent_phases = [pl_h["sent"], pl_t["sent"]]
        leaf_phases = []
        for pl in (pl_h, pl_t):
            lf = pl.pop("leaf_sent", None)
            if lf is not None:
                leaf_phases.append((lf, pl["bits"]))

        # ---- dual: S-stale own hat vs S-stale neighbor hat, gated off
        # during the S pipeline-fill rounds (both sides are still the
        # zero init then, so the gate is belt-and-braces explicitness —
        # the sim's fresh-edge rule promoted to the trainer)
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        fresh = (state.step >= s_depth).astype(jnp.float32)
        if self.eidx.num_directed:
            coef = self._d_sign * fresh
            if edge_part is not None:
                coef = coef * edge_part
            scale = dcfg.gadmm.alpha * dcfg.gadmm.rho
            own = jax.tree.map(lambda a: a[self._d_dst], hat_lag)
            lam_edge = jax.tree.map(
                lambda l, a, b: l + scale * _bmask(coef, l).astype(l.dtype)
                * (a.astype(l.dtype) - b.astype(l.dtype)),
                lam_edge, own, hat_edge)
        st = (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t)

        # ---- send / recv-start: merge the two phases' payloads (phases
        # partition the workers, so row w comes from exactly one) and
        # push into the ring; the oldest entry just consumed falls out
        d = sum(_leaf_sizes(jax.tree.leaves(theta)))
        mix = lambda a, b: jnp.where(_bmask(self.is_head, a), a, b)
        w_arr = state.inbox["radius"]
        merged = {
            "wire": mix(self._strip_wire(pl_h["wire"], d),
                        self._strip_wire(pl_t["wire"], d)),
            "radius": (mix(pl_h["radius"], pl_t["radius"])
                       if "radius" in pl_h else jnp.zeros_like(w_arr[0])),
            "bits": (mix(pl_h["bits"], pl_t["bits"]) if "bits" in pl_h
                     else jnp.zeros_like(state.inbox["bits"][0])),
            "sent": pl_h["sent"] | pl_t["sent"],
        }
        inbox = jax.tree.map(
            lambda buf, new: jnp.concatenate([buf[1:], new[None]], axis=0),
            state.inbox, merged)
        return st, hat_lag, f0, sent_phases, leaf_phases, inbox

    # ------------------------------------------------------- accounting ----
    def wire_row_bytes(self, d: int) -> int:
        """Actual bytes of one worker's exchanged wire-buffer row for d
        parameters — exactly what the ppermute moves: the row is zero-padded
        to the device-group multiple, and with pack_wire each of the group's
        devices nibble-packs its own D_pad/G shard (packed_len per shard, so
        the pack4 256-level granularity is paid per device)."""
        g = self._group_size()
        d_pad = sh.pad_to_multiple(d, g)
        if self.dcfg.gadmm.quantize:
            if self.dcfg.pack_wire:
                return g * packed_len(d_pad // g)
            return d_pad
        return 4 * d_pad

    def wire_bits_per_round(self, theta, sent_phases=None, leaf_phases=None):
        """Graph traffic per train step, matching the bytes on the wire.

        Without censoring (sent_phases=None) this bills what the ppermute
        exchanges actually move — a static int: per phase (2 in
        gauss-seidel, 1 in jacobi; overlap still performs both phases'
        exchanges) and per direction, each of the topology's E edges carries
        one wire-buffer row (wire_row_bytes: packing + group padding
        included) plus the quantizer sideband (quantizer.header_bits: R one
        f32 in global mode, one per tensor in per_tensor mode, plus the b
        i32).  For the chain E = W-1, the original accounting.  tests
        cross-check this against the constructed payload buffers and
        core.comm_model.

        With censoring, `sent_phases` is the list of per-phase (W,) transmit
        masks and the result is a traced scalar modelling the censored
        protocol: every directed edge always carries the 1-bit censor flag
        (censor.FLAG_BITS), and a direction's payload moves only when its
        source worker transmitted — a worker that is phase-inactive or
        censored is silent.  Directed payloads with source w per phase =
        deg(w) when sent[w], so the payload term is per_link *
        sum_w sent[w]*deg[w].

        In layerwise mode, `leaf_phases` is the list of per-phase
        (eff_leaf (W, L) bool, bits (W, L) i32) pairs and the billing is
        per transmitted leaf on the kernels/pack MIXED wire format
        (pack_mixed framing, the accounting twin of mixed_packed_len):
        every leaf slot carries a 1-bit flag on every directed edge, and a
        transmitted leaf costs 8 * bytes_l + header_bits() where bytes_l is
        packed_len(d_l) at <= 4 bits (nibble-packed segment) and d_l above
        (byte-wide), each sent leaf carrying its own (R f32, b i32) header.
        Group padding is not billed — the mixed format frames exact leaf
        sizes."""
        w = self.dcfg.num_workers
        n_edges = self.topo.num_edges
        if n_edges == 0:
            return 0
        leaves = jax.tree.leaves(theta)
        if leaf_phases is not None:
            sizes = _leaf_sizes(leaves)
            n_leaves = len(sizes)
            bytes_pk = jnp.asarray([packed_len(int(n)) for n in sizes],
                                   jnp.float32)
            bytes_raw = jnp.asarray(sizes, jnp.float32)
            deg = jnp.asarray(self.topo.degree, jnp.float32)
            total = jnp.zeros(())
            for eff, b in leaf_phases:
                bytes_l = jnp.where(b <= 4, bytes_pk, bytes_raw)  # (W, L)
                link = jnp.sum(eff.astype(jnp.float32)
                               * (8.0 * bytes_l + header_bits()), axis=1)
                total = (total
                         + 2 * n_edges * n_leaves * censor_mod.FLAG_BITS
                         + jnp.sum(deg * link))
            return total
        d = sum(_leaf_sizes(leaves))
        row_bits = 8 * self.wire_row_bytes(d)
        if self.dcfg.gadmm.quantize:
            n_r = (len(leaves) if self.dcfg.radius_mode == "per_tensor"
                   else 1)
            sideband = header_bits(num_radii=n_r)
        else:
            sideband = 0
        per_link = row_bits + sideband
        if sent_phases is None:
            n_phases = 2 if self.dcfg.mode == "gauss-seidel" else 1
            return n_phases * 2 * n_edges * per_link
        deg = jnp.asarray(self.topo.degree, jnp.float32)
        total = jnp.zeros(())
        for sent in sent_phases:
            total = (total + 2 * n_edges * censor_mod.FLAG_BITS
                     + per_link * jnp.sum(sent.astype(jnp.float32) * deg))
        return total

    def wire_bits_components(self, theta, sent_phases=None,
                             leaf_phases=None):
        """``wire_bits_per_round`` split into its (payload, header, flags)
        terms — the repro.obs telemetry/invariant decomposition.  Mirrors
        the three billing branches above argument-for-argument;
        payload + header + flags reassembles the total (bit-exactly on
        the static branch, up to float summation order on the traced
        censored/layerwise branches — obs.checks compares under a 1e-6
        relative tolerance).  Kept separate from ``wire_bits_per_round``
        so the committed exact-accounting expectations never change."""
        n_edges = self.topo.num_edges
        zero = jnp.zeros(())
        if n_edges == 0:
            return zero, zero, zero
        leaves = jax.tree.leaves(theta)
        if leaf_phases is not None:
            sizes = _leaf_sizes(leaves)
            n_leaves = len(sizes)
            bytes_pk = jnp.asarray([packed_len(int(n)) for n in sizes],
                                   jnp.float32)
            bytes_raw = jnp.asarray(sizes, jnp.float32)
            deg = jnp.asarray(self.topo.degree, jnp.float32)
            pay, hdr, flg = zero, zero, 0.0
            for eff, b in leaf_phases:
                bytes_l = jnp.where(b <= 4, bytes_pk, bytes_raw)  # (W, L)
                e = eff.astype(jnp.float32)
                pay = pay + jnp.sum(deg * jnp.sum(e * 8.0 * bytes_l,
                                                  axis=1))
                hdr = hdr + jnp.sum(deg * jnp.sum(e, axis=1)
                                    * header_bits())
                flg += 2 * n_edges * n_leaves * censor_mod.FLAG_BITS
            return pay, hdr, jnp.asarray(float(flg))
        d = sum(_leaf_sizes(leaves))
        row_bits = 8 * self.wire_row_bytes(d)
        if self.dcfg.gadmm.quantize:
            n_r = (len(leaves) if self.dcfg.radius_mode == "per_tensor"
                   else 1)
            sideband = header_bits(num_radii=n_r)
        else:
            sideband = 0
        if sent_phases is None:
            n_phases = 2 if self.dcfg.mode == "gauss-seidel" else 1
            links = n_phases * 2 * n_edges
            return (jnp.asarray(float(row_bits * links)),
                    jnp.asarray(float(sideband * links)), zero)
        deg = jnp.asarray(self.topo.degree, jnp.float32)
        links = sum(jnp.sum(s.astype(jnp.float32) * deg)
                    for s in sent_phases)
        flg = len(sent_phases) * 2 * n_edges * censor_mod.FLAG_BITS
        return row_bits * links, sideband * links, jnp.asarray(float(flg))
