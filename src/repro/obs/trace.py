"""Chrome trace-event (Perfetto-loadable) export.

Two producers share the format:

  * ``timeline_trace`` converts a sim ``Timeline``/``ArrayTimeline`` into
    one track per worker (pid 0): every transmission is an ``X`` span at
    its exact simulated start/airtime, annotated with bits, destination,
    round and censor/retransmit provenance; unicast sends additionally
    emit ``s``/``f`` flow arrows from the source span to the arrival on
    the destination track; drops/joins and retransmissions are instants;
    global round completions land on a "rounds" track.

  * ``TraceWriter`` records host wall-clock spans (pid 1) around trainer
    dispatch/drain/compile phases — each span also enters a
    ``jax.profiler.TraceAnnotation`` so the same names show up inside an
    XLA profile when one is being captured.

Load either output at https://ui.perfetto.dev or chrome://tracing.
"""
from __future__ import annotations

import contextlib
import json
import time

import numpy as np

_US = 1e6   # trace timestamps are microseconds


# ------------------------------------------------------------ TraceWriter ---
class TraceWriter:
    """Wall-clock span/instant recorder for host-side phases."""

    def __init__(self) -> None:
        self.events: list[dict] = []
        self._t0 = time.perf_counter()
        self.events.append({"ph": "M", "pid": 1, "tid": 0,
                            "name": "process_name",
                            "args": {"name": "host"}})

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * _US

    @contextlib.contextmanager
    def span(self, name: str, tid: int = 0, **args):
        ts = self._now_us()
        ann = None
        try:
            import jax
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        except Exception:           # profiler unavailable: spans still count
            ann = None
        try:
            yield
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self.events.append({"name": name, "ph": "X", "pid": 1,
                                "tid": tid, "ts": ts,
                                "dur": self._now_us() - ts,
                                "args": args or {}})

    def instant(self, name: str, tid: int = 0, **args) -> None:
        self.events.append({"name": name, "ph": "i", "s": "t", "pid": 1,
                            "tid": tid, "ts": self._now_us(),
                            "args": args or {}})

    def write(self, path: str) -> None:
        write_trace(path, self.events)


# ------------------------------------------------------- timeline -> trace --
def timeline_trace(timeline, max_events: int = 500_000) -> list[dict]:
    """Trace events for a sim run.  Consumes the shared
    ``TimelineBase.tx_fields()`` accessor, so the events engine and the
    vectorized engine export identically."""
    f = timeline.tx_fields()
    t, src, dst = f["t"], f["src"], f["dst"]
    bits, energy = f["bits"], f["energy_j"]
    air, attempt, rnd = f["airtime_s"], f["attempt"], f["rnd"]
    n_tx = len(t)
    events: list[dict] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": "sim"}}]
    for w in range(timeline.n):
        events.append({"ph": "M", "pid": 0, "tid": int(w),
                       "name": "thread_name",
                       "args": {"name": f"worker {w}"}})
    events.append({"ph": "M", "pid": 0, "tid": timeline.n,
                   "name": "thread_name", "args": {"name": "rounds"}})

    limit = max_events
    if n_tx > limit:
        print(f"repro.obs: trace truncated to first {limit} of {n_tx} "
              f"transmissions")
    for i in range(min(n_tx, limit)):
        dur = max(float(air[i]), 1e-9) * _US
        ts = float(t[i]) * _US
        name = (f"retx r{int(rnd[i])}" if attempt[i] > 0
                else f"tx r{int(rnd[i])}")
        events.append({
            "name": name, "ph": "X", "pid": 0, "tid": int(src[i]),
            "ts": ts, "dur": dur,
            "args": {"bits": float(bits[i]), "dst": int(dst[i]),
                     "round": int(rnd[i]), "attempt": int(attempt[i]),
                     "energy_j": float(energy[i])}})
        if attempt[i] > 0:
            events.append({"name": "retransmit", "ph": "i", "s": "t",
                           "pid": 0, "tid": int(src[i]), "ts": ts,
                           "args": {"attempt": int(attempt[i])}})
        if dst[i] >= 0:   # unicast: flow arrow source span -> arrival
            flow = {"cat": "link", "name": "link", "id": int(i)}
            events.append({**flow, "ph": "s", "pid": 0,
                           "tid": int(src[i]), "ts": ts})
            events.append({**flow, "ph": "f", "bp": "e", "pid": 0,
                           "tid": int(dst[i]), "ts": ts + dur})
    for w, td in getattr(timeline, "dropped_at", {}).items():
        events.append({"name": "drop", "ph": "i", "s": "p", "pid": 0,
                       "tid": int(w), "ts": float(td) * _US, "args": {}})
    for k, tk in enumerate(timeline.global_round_times()):
        events.append({"name": f"round {k}", "ph": "i", "s": "t",
                       "pid": 0, "tid": timeline.n,
                       "ts": float(tk) * _US, "args": {"round": k}})
    return events


# --------------------------------------------------------------- file I/O ---
def write_trace(path: str, events: list[dict]) -> None:
    with open(path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)


def load_trace(path: str) -> list[dict]:
    with open(path) as fh:
        doc = json.load(fh)
    return validate_trace(doc)


def validate_trace(doc) -> list[dict]:
    """The Perfetto-loadability contract the tests and REPRO_CHECK assert:
    JSON object format, every event carries ph/pid/tid (+ ts except
    metadata), X spans have non-negative dur, and per-track timestamps of
    complete events are monotone non-decreasing (both engines emit in
    time order)."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be a {'traceEvents': [...]} object")
    events = doc["traceEvents"]
    last: dict[tuple, float] = {}
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"bad trace event: {ev!r}")
        if "pid" not in ev or "tid" not in ev:
            raise ValueError(f"trace event missing pid/tid: {ev!r}")
        if ev["ph"] == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            raise ValueError(f"trace event missing ts: {ev!r}")
        if ev["ph"] == "X":
            if ev.get("dur", -1) < 0:
                raise ValueError(f"X event needs dur >= 0: {ev!r}")
            key = (ev["pid"], ev["tid"])
            if ts < last.get(key, float("-inf")):
                raise ValueError(
                    f"non-monotone ts on track {key}: {ts} after "
                    f"{last[key]}")
            last[key] = ts
    return events


def trace_tx_bits(events: list[dict]) -> float:
    """Sum of billed bits over tx spans — cross-checked against
    ``Timeline.total_bits()`` by the tests and REPRO_CHECK."""
    return float(np.sum([ev["args"]["bits"] for ev in events
                         if ev.get("ph") == "X" and ev.get("pid") == 0
                         and "bits" in ev.get("args", {})]))
