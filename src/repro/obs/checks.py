"""Opt-in live invariants (``REPRO_CHECK=1`` or DistConfig.check_invariants).

Promotes the accounting the test suite cross-checks offline into runtime
guards the CLIs can run on real traffic:

  * ``check_step_window`` — every drained step's billed
    ``wire_bits_per_round`` must equal payload + header + flags, and the
    non-layerwise components must match the closed-form recomputation
    from the constructed wire row (8 * wire_row_bytes + quantizer
    sideband per transmitted directed link, censor.FLAG_BITS per flag).
  * ``check_edge_mirrors`` — edge-state conservation: the two directed
    rows of every undirected edge hold the SAME canonical head->tail
    dual, bitwise (the lockstep mirror property PR 6's layout depends
    on).
  * ``check_timeline`` / ``check_trace`` — sim-side conservation: summary
    aggregates equal the per-transmission field sums, and the exported
    Perfetto trace bills exactly ``Timeline.total_bits()``.

All checks raise ``ObsCheckError`` with the offending numbers.
"""
from __future__ import annotations

import os

import numpy as np

ENV_FLAG = "REPRO_CHECK"


class ObsCheckError(AssertionError):
    pass


def enabled(dcfg=None) -> bool:
    if os.environ.get(ENV_FLAG, "") == "1":
        return True
    return bool(dcfg is not None
                and getattr(dcfg, "check_invariants", False))


def _close(name: str, got: float, want: float, rtol: float = 1e-6) -> None:
    if not np.isclose(got, want, rtol=rtol, atol=1e-6):
        raise ObsCheckError(f"repro.obs check failed: {name}: "
                            f"got {got!r}, want {want!r}")


# ------------------------------------------------------- trainer invariants -
def check_step_window(trainer, state, records) -> None:
    """Cross-check a drained window of step records against the wire
    format's closed form.  ``records`` are host-side step records (the
    return of MetricsLog.drain())."""
    import jax
    from repro.core import censor as censor_mod
    from repro.core.quantizer import header_bits

    dcfg = trainer.dcfg
    n_edges = trainer.topo.num_edges
    if n_edges == 0 or not records:
        return
    leaves = jax.tree.leaves(state.theta)
    d = sum(int(np.prod(l.shape[1:])) for l in leaves)
    row_bits = 8 * trainer.wire_row_bytes(d)
    n_r = len(leaves) if dcfg.radius_mode == "per_tensor" else 1
    sideband = header_bits(num_radii=n_r) if dcfg.gadmm.quantize else 0
    n_phases = 2 if dcfg.mode == "gauss-seidel" else 1
    dynamic = dcfg.censor is not None or dcfg.participation < 1.0
    for rec in records:
        m = rec["metrics"]
        need = ("wire_bits_per_round", "wire_bits_payload",
                "wire_bits_header", "wire_bits_flags")
        if any(k not in m for k in need):
            raise ObsCheckError("repro.obs check needs telemetry metrics "
                                f"{need}; enable DistConfig.telemetry")
        total = m["wire_bits_per_round"]
        payload, header, flags = (m["wire_bits_payload"],
                                  m["wire_bits_header"],
                                  m["wire_bits_flags"])
        _close(f"step {rec['step']}: payload+header+flags == total",
               payload + header + flags, total)
        if dcfg.layerwise is not None:
            n_leaves = len(leaves)
            _close(f"step {rec['step']}: layerwise flag bits",
                   flags,
                   n_phases * 2 * n_edges * n_leaves * censor_mod.FLAG_BITS)
            continue
        links = m["tx_links"] if dynamic else n_phases * 2 * n_edges
        _close(f"step {rec['step']}: payload == row_bits * links",
               payload, row_bits * links)
        _close(f"step {rec['step']}: header == sideband * links",
               header, sideband * links)
        _close(f"step {rec['step']}: flag bits",
               flags,
               n_phases * 2 * n_edges * censor_mod.FLAG_BITS
               if dynamic else 0.0)


def check_edge_mirrors(trainer, state) -> None:
    """Edge-state conservation: the two directed rows of every edge hold
    the same canonical head->tail dual.  Both endpoints apply the same
    increment each round (dual_update), but one endpoint computes it from
    its locally-quantized hat and the other from the decoded wire copy,
    so the mirror agrees to float rounding, not bitwise — the tolerance
    is a few ulps per step relative to the dual's scale, far below the
    O(increment) divergence an actual desync produces."""
    import jax

    eidx = trainer.eidx
    if not eidx.num_directed:
        return
    row = {(int(s), int(t)): i
           for i, (s, t) in enumerate(zip(eidx.src, eidx.dst))}
    rev = np.asarray([row[(int(t), int(s))]
                      for s, t in zip(eidx.src, eidx.dst)], np.int64)
    lam = jax.device_get(state.lam_edge)
    for i, leaf in enumerate(jax.tree.leaves(lam)):
        a = np.asarray(leaf, np.float64)
        if a.size == 0:                       # zero-size leaves carry no dual
            continue
        tol = 1e-3 * float(np.max(np.abs(a))) + 1e-8
        diff = np.abs(a[rev] - a).reshape(len(rev), -1).max(axis=1)
        if np.any(diff > tol):
            bad = np.flatnonzero(diff > tol)
            raise ObsCheckError(
                f"repro.obs check failed: lam_edge mirror broken on leaf "
                f"{i}, directed rows {bad[:8].tolist()} (of "
                f"{eidx.num_directed}), max diff {diff.max():.3e} > "
                f"{tol:.3e}")


# ----------------------------------------------------------- sim invariants -
def check_timeline(timeline) -> None:
    """Summary aggregates == per-transmission field sums, and per-worker
    round completion times are monotone."""
    f = timeline.tx_fields()
    _close("timeline total_bits", timeline.total_bits(),
           float(np.sum(f["bits"])), rtol=1e-9)
    _close("timeline total_energy_j", timeline.total_energy_j(),
           float(np.sum(f["energy_j"])), rtol=1e-9)
    _close("timeline per_worker_energy sum",
           float(np.sum(timeline.per_worker_energy_j())),
           timeline.total_energy_j(), rtol=1e-9)
    if timeline.retransmissions() != int(np.sum(f["attempt"] > 0)):
        raise ObsCheckError("repro.obs check failed: retransmission count")
    times = timeline.global_round_times()
    if any(b < a for a, b in zip(times, times[1:])):
        raise ObsCheckError("repro.obs check failed: global round times "
                            "not monotone")


def check_trace(events, timeline) -> None:
    """The exported trace is Perfetto-valid and bills exactly the
    timeline's bits (skipped if the trace was truncated)."""
    from repro.obs.trace import trace_tx_bits, validate_trace

    validate_trace({"traceEvents": events})
    n_tx = len(timeline.tx_fields()["t"])
    n_spans = sum(1 for ev in events
                  if ev.get("ph") == "X" and ev.get("pid") == 0)
    if n_spans < n_tx:      # truncated export: bits won't reconcile
        return
    _close("trace tx bits == timeline.total_bits()",
           trace_tx_bits(events), timeline.total_bits(), rtol=1e-9)
