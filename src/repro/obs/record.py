"""Structured run records: append-only JSONL under ``repro.obs/v1``.

Every run artifact the repo emits — trainer metrics, sim round traces,
benchmark sections — goes through one schema so the report CLI, the CI
validators and future regression gates all read the same shape.  A run
file is newline-delimited JSON whose FIRST line is always the manifest
(config + stable hash, git SHA, jax versions, platform, seed, topology);
subsequent lines are ``step``/``round`` records and an optional closing
``summary``.

`MetricsLog` is the writer.  Its contract with the jitted trainer step:
``append(step, metrics)`` stores the device arrays without looking at
them (no host sync); ``drain()`` fetches the whole pending window with
ONE batched ``jax.device_get`` and writes the step records.  Telemetry
therefore costs one transfer per ``log_every`` steps instead of a sync
per step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform as _platform
import subprocess
import time
from typing import Any

SCHEMA = "repro.obs/v1"
RECORD_KINDS = ("manifest", "step", "round", "summary", "bench")


# ----------------------------------------------------------- jsonify --------
def _jsonify(x):
    """Best-effort conversion of metric values (numpy/jax scalars and
    arrays, dataclasses, tuples) to plain JSON types."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {k: _jsonify(v) for k, v in dataclasses.asdict(x).items()}
    if isinstance(x, dict):
        return {str(k): _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if hasattr(x, "tolist"):       # numpy scalar/array, jax host array
        return _jsonify(x.tolist())
    if hasattr(x, "item"):
        return _jsonify(x.item())
    return repr(x)


def config_hash(cfg: Any) -> str:
    """Stable 12-hex digest of a config dict/dataclass: canonical JSON
    (sorted keys, repr fallback for exotic leaves) through sha256."""
    blob = json.dumps(_jsonify(cfg), sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def _git_sha() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=5)
        return out.stdout.strip() or None
    except Exception:
        return None


# ---------------------------------------------------- record constructors ---
def manifest_record(config: Any = None, *, seed: int | None = None,
                    topology: str | None = None,
                    num_workers: int | None = None,
                    extra: dict | None = None) -> dict:
    """First line of every run file.  Captures enough to re-run and to
    refuse apples-to-oranges diffs in the report CLI."""
    try:
        import jax
        import jaxlib
        jv, jlv, backend = (jax.__version__, jaxlib.__version__,
                            jax.default_backend())
    except Exception:                                     # pragma: no cover
        jv = jlv = backend = None
    cfg = _jsonify(config) if config is not None else {}
    rec = {
        "schema": SCHEMA,
        "kind": "manifest",
        "config": cfg,
        "config_hash": config_hash(config) if config is not None else None,
        "git_sha": _git_sha(),
        "jax_version": jv,
        "jaxlib_version": jlv,
        "backend": backend,
        "platform": _platform.platform(),
        "seed": seed,
        "topology": {"kind": topology, "num_workers": num_workers},
        "time_unix": time.time(),
    }
    if extra:
        rec.update(_jsonify(extra))
    return rec


def step_record(step: int, metrics: dict, *, wall_s: float | None = None
                ) -> dict:
    return {"schema": SCHEMA, "kind": "step", "step": int(step),
            "wall_s": wall_s, "metrics": _jsonify(metrics)}


def round_record(rnd: int, *, t_s: float | None = None,
                 loss: float | None = None, metrics: dict | None = None
                 ) -> dict:
    return {"schema": SCHEMA, "kind": "round", "round": int(rnd),
            "t_s": t_s, "loss": _jsonify(loss),
            "metrics": _jsonify(metrics or {})}


def summary_record(summary: dict) -> dict:
    return {"schema": SCHEMA, "kind": "summary",
            "summary": _jsonify(summary)}


def bench_record(bench: str, payload: Any) -> dict:
    """Wrapper for a benchmark section (``bench_wire`` rows, ``bench_sim``
    scenario dicts) — the committed BENCH_*.json keep their historical
    shapes; this record carries them inside the schema envelope."""
    return {"schema": SCHEMA, "kind": "bench", "bench": str(bench),
            "payload": _jsonify(payload)}


# ----------------------------------------------------------- validation -----
def _fail(msg: str, rec) -> None:
    raise ValueError(f"repro.obs: invalid record: {msg}: "
                     f"{json.dumps(rec, default=repr)[:200]}")


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_record(rec: dict) -> dict:
    """Schema check used by MetricsLog.write, the tests, and CI.  Returns
    the record so call sites can chain it."""
    if not isinstance(rec, dict):
        _fail("not a dict", rec)
    if rec.get("schema") != SCHEMA:
        _fail(f"schema != {SCHEMA!r}", rec)
    kind = rec.get("kind")
    if kind not in RECORD_KINDS:
        _fail(f"kind {kind!r} not in {RECORD_KINDS}", rec)
    if kind == "manifest":
        if not isinstance(rec.get("config"), dict):
            _fail("manifest.config must be a dict", rec)
        topo = rec.get("topology")
        if not isinstance(topo, dict) or "kind" not in topo \
                or "num_workers" not in topo:
            _fail("manifest.topology needs kind/num_workers", rec)
        ch = rec.get("config_hash")
        if ch is not None and not (isinstance(ch, str) and len(ch) == 12):
            _fail("manifest.config_hash must be 12 hex chars", rec)
    elif kind == "step":
        if not isinstance(rec.get("step"), int):
            _fail("step.step must be an int", rec)
        m = rec.get("metrics")
        if not isinstance(m, dict) or not m:
            _fail("step.metrics must be a non-empty dict", rec)
        for k, v in m.items():
            if not (_is_num(v) or isinstance(v, list)):
                _fail(f"step.metrics[{k!r}] must be number or list", rec)
    elif kind == "round":
        if not isinstance(rec.get("round"), int):
            _fail("round.round must be an int", rec)
        if rec.get("t_s") is not None and not _is_num(rec["t_s"]):
            _fail("round.t_s must be a number", rec)
    elif kind == "summary":
        if not isinstance(rec.get("summary"), dict):
            _fail("summary.summary must be a dict", rec)
    elif kind == "bench":
        if not isinstance(rec.get("bench"), str):
            _fail("bench.bench must be a string", rec)
        if "payload" not in rec:
            _fail("bench.payload missing", rec)
    # every record must survive a JSON round-trip unchanged
    if json.loads(json.dumps(rec)) != rec:
        _fail("record is not JSON round-trippable", rec)
    return rec


def validate_run(path: str) -> list[dict]:
    """Validate a JSONL run file: every line a valid record, first line
    the manifest.  Returns the parsed records."""
    with open(path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    if not recs:
        raise ValueError(f"repro.obs: empty run file {path}")
    for rec in recs:
        validate_record(rec)
    if recs[0]["kind"] != "manifest":
        raise ValueError(f"repro.obs: first record of {path} must be the "
                         f"manifest, got {recs[0]['kind']!r}")
    return recs


# ----------------------------------------------- committed BENCH_* shapes ---
def validate_bench_wire(doc) -> None:
    """Shape of the committed BENCH_wire.json: a list of row dicts; plain
    rows carry impl/arch timing fields, section rows ('state_layout',
    'layerwise') their own fixed keys.  CI gates depend on these shapes —
    new sections must extend this validator."""
    if not isinstance(doc, list) or not doc:
        raise ValueError("BENCH_wire.json must be a non-empty list")
    known = {None, "state_layout", "layerwise"}
    for row in doc:
        if not isinstance(row, dict):
            raise ValueError(f"BENCH_wire row must be a dict: {row!r}")
        section = row.get("section")
        if section not in known:
            raise ValueError(f"BENCH_wire: unknown section {section!r} "
                             f"(extend validate_bench_wire)")
        if section is None and not {"impl", "num_workers"} <= set(row):
            raise ValueError(
                f"BENCH_wire plain row needs impl/num_workers: {row!r}")


def validate_bench_sim(doc) -> None:
    """Shape of the committed BENCH_sim.json: exactly the 'scenarios' and
    'scale' sections (the CI gate asserts this set literally)."""
    if not isinstance(doc, dict) or set(doc) != {"scenarios", "scale"}:
        raise ValueError("BENCH_sim.json must have exactly the "
                         "'scenarios' and 'scale' sections, got "
                         f"{sorted(doc) if isinstance(doc, dict) else doc!r}")
    for key in ("scenarios", "scale"):
        rows = doc[key]
        if not isinstance(rows, list) \
                or not all(isinstance(r, dict) for r in rows):
            raise ValueError(f"BENCH_sim.{key} must be a list of row dicts")
    if not doc["scenarios"]:
        raise ValueError("BENCH_sim.scenarios must be non-empty")


def write_bench(path: str, doc, kind: str) -> None:
    """Validate-then-write for the benchmark writers.  The committed
    artifact content stays EXACTLY what it always was (CI parses it
    directly); the schema envelope is enforced at write time via
    bench_record/validate_record on the same payload."""
    validate_record(bench_record(kind, doc))
    {"wire": validate_bench_wire, "sim": validate_bench_sim}[kind](
        _jsonify(doc))
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)


# ------------------------------------------------------------ MetricsLog ----
class MetricsLog:
    """Append-only JSONL writer with a no-sync device-side buffer.

    path=None keeps records in memory only (``self.records``) — the tests
    and the parity suites use that mode.  ``append`` never touches the
    arrays; ``drain`` fetches the whole window in one ``jax.device_get``.
    """

    def __init__(self, path: str | None = None, manifest: dict | None = None,
                 log_every: int = 10) -> None:
        assert log_every >= 1, log_every
        self.path = path
        self.log_every = int(log_every)
        self.records: list[dict] = []
        self._fh = open(path, "w") if path else None
        self._pending: list[tuple[int, dict]] = []
        self._last_drain = time.perf_counter()
        if manifest is not None:
            self.write(manifest)

    # -- writer ---------------------------------------------------------
    def write(self, rec: dict) -> dict:
        validate_record(rec)
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, default=repr) + "\n")
            self._fh.flush()
        return rec

    # -- jit-side buffer ------------------------------------------------
    def append(self, step: int, metrics: dict) -> None:
        """Buffer one step's device metrics.  NO host sync happens here —
        the dict values stay device arrays until drain()."""
        self._pending.append((int(step), metrics))

    def maybe_drain(self, step: int) -> list[dict]:
        if (step + 1) % self.log_every == 0:
            return self.drain()
        return []

    def drain(self) -> list[dict]:
        """One batched device_get over the pending window; returns the
        step records written (newest last)."""
        if not self._pending:
            return []
        import jax
        steps = [s for s, _ in self._pending]
        host = jax.device_get([m for _, m in self._pending])
        now = time.perf_counter()
        wall = (now - self._last_drain) / len(self._pending)
        self._last_drain = now
        out = [self.write(step_record(s, m, wall_s=wall))
               for s, m in zip(steps, host)]
        self._pending.clear()
        return out

    # -- lifecycle ------------------------------------------------------
    def close(self, summary: dict | None = None) -> None:
        self.drain()
        if summary is not None:
            self.write(summary_record(summary))
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
