"""repro.obs — unified run telemetry.

One observability path for the trainer, the discrete-event sim and the
benchmarks: structured JSONL run records (``obs.record``, schema
``repro.obs/v1``), Chrome trace-event export (``obs.trace``), and opt-in
live invariants (``obs.checks``, env ``REPRO_CHECK=1``)."""
from repro.obs import checks, record, trace
from repro.obs.record import (MetricsLog, bench_record, manifest_record,
                              round_record, step_record, summary_record,
                              validate_record, validate_run)
from repro.obs.trace import (TraceWriter, load_trace, timeline_trace,
                             validate_trace, write_trace)

__all__ = [
    "checks", "record", "trace",
    "MetricsLog", "bench_record", "manifest_record", "round_record",
    "step_record", "summary_record", "validate_record", "validate_run",
    "TraceWriter", "load_trace", "timeline_trace", "validate_trace",
    "write_trace",
]
