"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
        vocab=256000, head_dim=192, activation="relu2", rope_theta=1e4,
        qkv_bias=False, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
        vocab=251, head_dim=16, activation="relu2", rope_theta=1e4, **kw)
