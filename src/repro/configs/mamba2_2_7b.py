"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.models.config import ArchConfig, SSMConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=50280, activation="silu",
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                      chunk=256), **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=137, activation="silu",
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                      chunk=8), **kw)
