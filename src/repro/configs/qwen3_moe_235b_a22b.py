"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family]"""
from repro.models.config import ArchConfig, MoEConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=0,
        head_dim=128, vocab=151936, activation="silu", rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                      moe_every=1), **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=0,
        head_dim=24, vocab=149, activation="silu", rope_theta=1e6,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, moe_every=1,
                      capacity_factor=2.0),  # drop-free: cf >= E/k
        **kw)
