"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

Adaptation: the shared attention block is invoked once per 6 Mamba layers
with a 4096 sliding window so the hybrid's attention state is bounded
(qualifies for long_500k decode).
"""
from repro.models.config import ArchConfig, SSMConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
        vocab=32000, activation="silu", rope_theta=1e4,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                      chunk=128),
        attn_every=6, sliding_window=4096, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=131, activation="silu", rope_theta=1e4,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                      chunk=8),
        attn_every=2, sliding_window=16, **kw)
