"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
        vocab=152064, activation="silu", qkv_bias=True, rope_theta=1e6, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, d_ff=384,
        vocab=211, activation="silu", qkv_bias=True, rope_theta=1e6, **kw)
