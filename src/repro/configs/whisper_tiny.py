"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv/mel frontend STUBBED (pipeline supplies 1500 frame embeddings).
[arXiv:2212.04356]

Adaptation: RoPE decoder positions instead of learned embeddings (same cost);
RMSNorm instead of LayerNorm.
"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
        vocab=51865, activation="gelu", rope_theta=1e4,
        encoder_layers=4, encoder_frames=1500, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=139, activation="gelu", rope_theta=1e4,
        encoder_layers=2, encoder_frames=32, **kw)
