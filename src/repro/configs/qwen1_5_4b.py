"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
        vocab=151936, activation="silu", qkv_bias=True, rope_theta=1e6, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-4b-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=173, activation="silu", qkv_bias=True, rope_theta=1e6, **kw)
