"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192, vocab=202048, MoE 128 experts top-1 + shared expert, alternating
dense/MoE layers, early fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E family]"""
from repro.models.config import ArchConfig, MoEConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=16384,
        head_dim=128, vocab=202048, activation="silu", rope_theta=5e5,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      shared_expert_ff=8192, moe_every=2), **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="llama4-maverick-smoke", family="moe",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
        head_dim=24, vocab=151, activation="silu", rope_theta=5e5,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=96,
                      shared_expert_ff=96, moe_every=2,
                      capacity_factor=4.0), **kw)  # drop-free: cf >= E/k
