"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5 local (sliding window) : 1 global layers, 128k context.
[hf:google/gemma-3-1b-pt family]

Adaptation note: gemma3 uses GeGLU; our gated MLP uses SiLU gating (same
structure/FLOPs).  Sliding window 1024 as in gemma3.
"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
        head_dim=128, vocab=262144, activation="silu", rope_theta=1e6,
        sliding_window=1024, global_every=6, tie_embeddings=True, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
        head_dim=32, vocab=193, activation="silu", rope_theta=1e6,
        sliding_window=8, global_every=2, tie_embeddings=True, **kw)
