"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

Vision tower + projector are stubbed: the pipeline supplies 1152 pre-projected
patch embeddings (anyres: base 576 + one 576-patch tile), prepended to the
text sequence (early fusion).
"""
from repro.models.config import ArchConfig


def config(**kw) -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=32000, activation="silu", rope_theta=1e6, n_patches=1152, **kw)


def smoke_config(**kw) -> ArchConfig:
    return ArchConfig(
        name="llava-next-smoke", family="vlm",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=127, activation="silu", rope_theta=1e6, n_patches=12, **kw)
