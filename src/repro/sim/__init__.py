"""repro.sim — deterministic discrete-event simulator for Q-GADMM.

Everything else in this repo executes Q-GADMM in idealized lockstep rounds
and reconstructs network cost after the fact from core.comm_model closed
forms.  This subsystem *plays the algorithm out* message-by-message over a
modeled network: every worker is an actor running the real per-worker
Q-GADMM update (the exact row math of core.gadmm.graph_phase /
dist.qgadmm.QGADMMTrainer.phase_compute — no reimplementation), and every
transmission is an explicit message traversing a per-link channel with
latency, bandwidth, jitter, i.i.d. loss + retransmit, priced through
core.comm_model.RadioConfig.  Heterogeneous compute, stragglers, worker
drops, and bounded-staleness asynchrony become first-class scenarios.

Keystone contract (locked by tests/test_sim.py): under an ideal network —
zero latency, lossless, homogeneous compute, staleness 0 — the simulator's
per-round worker states are bit-identical to core.gadmm.graph_step (and,
in trainer mode, to QGADMMTrainer.make_train_step()), for every topology
and with censoring on or off.

Scale: ``SimConfig.engine='vectorized'`` switches graph-mode runs to
sim.vectorized — the same protocol replayed as whole-graph array ops
(states stay bit-identical to the event loop; tests/test_sim.py locks
the parity), which is what makes N=10^4 hierarchical scenarios with
partial participation (SimConfig.participation, FaultPlan.join_round)
run in seconds.

Modules:
  engine     — deterministic event loop / clock (repeatable tie-breaking)
  network    — channel + fault models (latency/jitter/loss/stragglers/
               drops/joins)
  worker     — GraphActor / TrainerActor: the per-worker protocol machines
  timeline   — per-worker wall-clock + Joules accountant, *-to-target
               traces (Timeline per-message, ArrayTimeline array-backed)
  runner     — SimConfig / simulate() / simulate_trainer() entry points
  vectorized — the large-N fast path (one array op per phase wave)
"""
from .engine import Engine, SimLivenessError
from .network import ComputeModel, FaultPlan, Network, NetworkConfig
from .runner import (SimConfig, SimResult, participation_schedule, simulate,
                     simulate_trainer)
from .timeline import ArrayTimeline, Timeline
from .vectorized import simulate_vectorized

__all__ = [
    "ArrayTimeline", "ComputeModel", "Engine", "FaultPlan", "Network",
    "NetworkConfig", "SimConfig", "SimLivenessError", "SimResult",
    "Timeline", "participation_schedule", "simulate", "simulate_trainer",
    "simulate_vectorized",
]
