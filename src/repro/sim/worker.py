"""Q-GADMM actors for the event-driven runtime.

Each worker is a small protocol state machine around the *real* per-worker
update math — nothing numeric is reimplemented here:

  * :class:`GraphActor` replays ``core.gadmm.graph_phase`` /
    ``graph_dual_update`` (the CQ-GGADMM graph reference) on a local view,
  * :class:`TrainerActor` replays ``dist.qgadmm.QGADMMTrainer``'s
    ``phase_compute`` / ``phase_apply`` / ``dual_update`` methods (the
    unsharded reference step of the distributed trainer).

Local views.  Row n of every reference function depends only on row n of
its inputs plus n's neighbor rows of the hat state (through 0/1-masked
sums) — so an actor keeps a full-shaped *local view* whose own row and
neighbor rows are maintained exactly (neighbor rows only ever change by
applying received messages through the same reconstruction code the
lockstep reference runs) while all unrelated rows are don't-care.  Under
an ideal network this makes the actor's own row bit-identical to the
lockstep implementation, which tests/test_sim.py asserts per round.

Protocol (two-phase Gauss-Seidel, bounded staleness S = `staleness`):

  * a head may start its round-k phase once every live neighbor's last
    applied round >= k-1-S; a tail once every live head neighbor reached
    round k-S (S=0 is the barriered schedule: tails consume the heads'
    fresh round-k hats, exactly the lockstep sweep),
  * after its phase the worker broadcasts one payload — quantized levels
    + (R, b) sideband, or the 1-bit censor flag — through sim.network,
  * the worker completes round k (per-edge dual update, snapshot, k+1)
    once its own phase is done and every live neighbor's applied round
    >= k-S.

Messages on one directed link are applied strictly in round order (the
channel is FIFO and the actor buffers anything early) because the
quantizer is delta-coded: reconstruction of round k+1 requires the hat
state after round k.  Dropped neighbors are detected via the network's
peer-down notification; the actor stops waiting on them and freezes the
shared edge's dual instead of integrating a stale residual forever.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Msg:
    src: int
    rnd: int
    sent: bool          # False = censor flag only
    body: dict[str, Any]
    bits: float


class BaseActor:
    """Shared Gauss-Seidel protocol machine; numeric hooks in subclasses:

    _phase(key) -> (sent: bool, body: dict, payload_bits: float)
    _apply(j, msg) -> None           (fold a neighbor's payload in)
    _dual_update() -> None
    _snapshot() -> dict
    """

    def __init__(self, i, topo, *, engine, network, timeline, compute,
                 rounds, staleness=0, drop_round=None, seed=0):
        self.i = int(i)
        self.topo = topo
        self.engine = engine
        self.network = network
        self.timeline = timeline
        self.compute = compute
        self.rounds = int(rounds)
        self.staleness = int(staleness)
        self.drop_round = drop_round
        self.is_head = bool(topo.head_mask[self.i])
        self.neighbors = [int(j) for j in topo.neighbors(self.i)]
        self.rng = np.random.default_rng([seed, 3, self.i])

        self.rnd = 0
        self.phase_done = False
        self.computing = False
        self.dropped = False
        self.radio_busy = 0.0
        self.nbr_round = {j: -1 for j in self.neighbors}
        self.dead: set[int] = set()
        self._early: dict[int, dict[int, Msg]] = {j: {} for j in self.neighbors}
        self.sent_log: list[bool] = []

    # ------------------------------------------------------------ schedule --
    def start(self) -> None:
        self._try_phase()

    def _live(self):
        return (j for j in self.neighbors if j not in self.dead)

    def _phase_ready(self) -> bool:
        need = self.rnd - 1 - self.staleness if self.is_head \
            else self.rnd - self.staleness
        return all(self.nbr_round[j] >= need for j in self._live())

    def _complete_ready(self) -> bool:
        need = self.rnd - self.staleness
        return all(self.nbr_round[j] >= need for j in self._live())

    def _try_phase(self) -> None:
        if self.dropped or self.computing or self.phase_done \
                or self.rnd >= self.rounds:
            return
        if self.drop_round is not None and self.rnd >= self.drop_round:
            self.dropped = True
            self.network.announce_drop(self.i)
            return
        if not self._phase_ready():
            return
        self.computing = True
        t_start = max(self.engine.now, self.radio_busy)
        dt = self.compute.sample(self.i, self.rng)
        self.engine.at(t_start + dt, self._on_compute_done)

    def _on_compute_done(self) -> None:
        key = self._phase_key()
        sent, body, bits = self._phase(key)
        self.sent_log.append(bool(sent))
        msg = Msg(src=self.i, rnd=self.rnd, sent=bool(sent), body=body,
                  bits=float(bits))
        self.radio_busy = self.network.broadcast(self.i, float(bits), msg)
        self.computing = False
        self.phase_done = True
        self._try_complete()

    def _try_complete(self) -> None:
        if self.dropped or not self.phase_done or not self._complete_ready():
            return
        self._dual_update()
        self.timeline.record_round(self.i, self.rnd, self.engine.now)
        self.timeline.record_snapshot(self.i, self.rnd, self._snapshot())
        self.rnd += 1
        self.phase_done = False
        self._try_phase()

    # ------------------------------------------------------------ receiving --
    def on_message(self, msg: Msg) -> None:
        if self.dropped:
            return
        j = msg.src
        # delta-coded payloads apply strictly in round order; the FIFO
        # channel makes out-of-order arrival impossible, the buffer keeps
        # the invariant explicit (and guards any future transport).
        self._early[j][msg.rnd] = msg
        while self.nbr_round[j] + 1 in self._early[j]:
            m = self._early[j].pop(self.nbr_round[j] + 1)
            if m.sent:
                self._apply(j, m)
            self.nbr_round[j] += 1
        self._try_phase()
        self._try_complete()

    def on_peer_down(self, j: int) -> None:
        if self.dropped or j in self.dead:
            return
        self.dead.add(int(j))
        self._peer_down_hook(int(j))
        self._try_phase()
        self._try_complete()

    # ---------------------------------------------------------------- hooks --
    def _phase_key(self):
        raise NotImplementedError

    def _phase(self, key):
        raise NotImplementedError

    def _apply(self, j: int, msg: Msg) -> None:
        raise NotImplementedError

    def _dual_update(self) -> None:
        raise NotImplementedError

    def _snapshot(self) -> dict:
        raise NotImplementedError

    def _peer_down_hook(self, j: int) -> None:
        pass


class GraphActor(BaseActor):
    """Actor running core.gadmm.graph_phase on a local view.

    `fns` is the shared jitted function table built once by the runner
    (sim.runner._graph_fns): phase / apply / dual — one compilation for
    all N actors.
    """

    def __init__(self, i, topo, *, state0, fns, keys, cfg, payload_bits,
                 flag_bits, **kw):
        super().__init__(i, topo, **kw)
        self.fns = fns
        self.keys = keys          # (rounds, 2, key) beacon: [k][head?0:1]
        self.cfg = cfg
        self.payload_bits = float(payload_bits)
        self.flag_bits = float(flag_bits)
        self.theta = state0.theta
        self.hat = state0.theta_hat
        self.lam = state0.lam
        self.radius = state0.radius
        self.bits = state0.bits
        self.active = jnp.asarray(topo.head_mask if self.is_head
                                  else ~topo.head_mask)
        self.edge_alive = np.ones((topo.num_edges,), np.float32)
        self._edge_of = {}
        for e, (h, t) in enumerate(topo.edges):
            if int(h) == self.i:
                self._edge_of[int(t)] = e
            elif int(t) == self.i:
                self._edge_of[int(h)] = e

    def _phase_key(self):
        return self.keys[self.rnd][0 if self.is_head else 1]

    def _phase(self, key):
        (self.theta, self.hat, self.radius, self.bits,
         sent_i, qlev_i, hat_i, r_i, b_i) = self.fns["phase"](
            self.theta, self.hat, self.lam, self.radius, self.bits,
            self.active, key, jnp.asarray(self.rnd, jnp.int32), self.i)
        if not bool(sent_i):
            return False, {}, self.flag_bits
        # The wire carries (qlev, R, b) — that is what payload_bits prices
        # — and the receiver's dequantize_rows(qlev, hat_prev, R, b) is the
        # same arithmetic that committed hat_i on the sender.  The message
        # also transports the committed row itself: recomputing it in a
        # separately jitted program is NOT guaranteed bit-stable (XLA may
        # FMA-contract a*b+c differently per compilation), and the
        # keystone contract locks the sim to the lockstep reference
        # bit-for-bit.  tests/test_sim.py checks the codec roundtrip
        # against the shipped row.
        body = {"hat": hat_i, "qlev": qlev_i, "radius": r_i, "bits": b_i} \
            if self.cfg.quantize else {"hat": hat_i}
        return True, body, self.payload_bits

    def _apply(self, j, msg):
        self.hat = self.fns["apply"](self.hat, j, msg.body["hat"])

    def _edge_mask(self) -> np.ndarray:
        """1.0 on live incident edges whose neighbor hat is round-fresh.

        Barriered (staleness 0) completion implies nbr_round[j] == rnd, so
        the mask is all-ones there (bit-parity preserved; x*1.0 is exact).
        In async mode a dual step is taken only when the edge has this
        round's information — integrating a stale residual every local
        round makes the per-endpoint dual copies drift apart and wrecks
        the fixed point."""
        mask = self.edge_alive.copy()
        for j, e in self._edge_of.items():
            if j not in self.dead and self.nbr_round[j] < self.rnd:
                mask[e] = 0.0
        return mask

    def _dual_update(self):
        self.lam = self.fns["dual"](self.lam, self.hat,
                                    jnp.asarray(self._edge_mask()))

    def _peer_down_hook(self, j):
        e = self._edge_of.get(j)
        if e is not None:
            self.edge_alive[e] = 0.0

    def _snapshot(self):
        lam_rows = {self._edge_of[j]: np.asarray(self.lam[self._edge_of[j]])
                    for j in self.neighbors
                    if int(self.topo.edges[self._edge_of[j], 0]) == self.i}
        return dict(theta=np.asarray(self.theta[self.i]),
                    hat=np.asarray(self.hat[self.i]),
                    radius=np.asarray(self.radius[self.i]),
                    bits=np.asarray(self.bits[self.i]),
                    sent=self.sent_log[-1], lam_rows=lam_rows)


class TrainerActor(BaseActor):
    """Actor replaying QGADMMTrainer's unsharded reference step pieces.

    The local view is the trainer's full stacked 9-tuple state; `fns`
    (sim.runner._trainer_fns) wraps the trainer's phase_compute /
    phase_apply / dual_update methods, jitted once for all actors.
    """

    def __init__(self, i, topo, *, st0, batch, fns, keys, trainer,
                 payload_bits, flag_bits, **kw):
        super().__init__(i, topo, **kw)
        self.st = st0
        self.batch = batch
        self.fns = fns
        self.keys = keys
        self.trainer = trainer
        self.payload_bits = float(payload_bits)
        self.flag_bits = float(flag_bits)
        self.quantize = trainer.dcfg.gadmm.quantize
        self.active = jnp.asarray(topo.head_mask if self.is_head
                                  else ~topo.head_mask)
        # port c of worker i <-> neighbor topo.port[i, c]
        self._port_of = {int(p): c for c, p in enumerate(topo.port[self.i])
                         if p >= 0}
        self.port_alive = np.asarray(topo.port >= 0, np.float32)

    def _phase_key(self):
        return self.keys[self.rnd][0 if self.is_head else 1]

    def _phase(self, key):
        self.st, sent_i, hat_row, wire_i, r_i, b_i = self.fns["phase"](
            self.st, self.batch, self.active, key,
            jnp.asarray(self.rnd, jnp.int32), self.i)
        if not bool(sent_i):
            return False, {}, self.flag_bits
        # wire_i/(R, b) are the billed wire content; hat_row is the
        # committed reconstruction the receivers store (see GraphActor:
        # cross-program recompute is not FMA-stable, and the trainer's
        # in-program receiver path is bit-identical to the sender's commit
        # — checked by the sim-vs-trainer parity suite).
        body = {"hat": hat_row, "wire": wire_i}
        if self.quantize:
            body["radius"] = r_i
            body["bits"] = b_i
        return True, body, self.payload_bits

    def _apply(self, j, msg):
        self.st = self.fns["apply"](self.st, self._port_of[j], self.i,
                                    msg.body["hat"])

    def _dual_update(self):
        # same fresh-edge gating as GraphActor._edge_mask (row i only; the
        # other rows of the local view are don't-care)
        mask = self.port_alive.copy()
        for j, c in self._port_of.items():
            if j not in self.dead and self.nbr_round[j] < self.rnd:
                mask[self.i, c] = 0.0
        self.st = self.fns["dual"](self.st, jnp.asarray(mask))

    def _peer_down_hook(self, j):
        self.port_alive = self.port_alive.copy()
        self.port_alive[self.i, self._port_of[j]] = 0.0

    def _snapshot(self):
        import jax
        (theta, hat, hat_nbr, lam_nbr, radius, bits, mu, nu, t) = self.st
        row = lambda tree: jax.tree.map(
            lambda a: np.asarray(a[self.i]), tree)
        return dict(theta=row(theta), hat=row(hat),
                    hat_nbr=tuple(row(h) for h in hat_nbr),
                    lam_nbr=tuple(row(l) for l in lam_nbr),
                    radius=np.asarray(radius[self.i]),
                    bits=np.asarray(bits[self.i]),
                    sent=self.sent_log[-1])
