"""Q-GADMM actors for the event-driven runtime.

Each worker is a small protocol state machine around the *real* per-worker
update math — nothing numeric is reimplemented here:

  * :class:`GraphActor` replays ``core.gadmm.graph_phase`` /
    ``graph_dual_update`` (the CQ-GGADMM graph reference) on a local view,
  * :class:`TrainerActor` replays ``dist.qgadmm.QGADMMTrainer``'s
    ``phase_compute`` / ``phase_apply`` / ``dual_update`` methods (the
    unsharded reference step of the distributed trainer).

Local views.  Row n of every reference function depends only on row n of
its inputs plus n's neighbor rows of the hat state (through 0/1-masked
sums) — so an actor keeps a full-shaped *local view* whose own row and
neighbor rows are maintained exactly (neighbor rows only ever change by
applying received messages through the same reconstruction code the
lockstep reference runs) while all unrelated rows are don't-care.  Under
an ideal network this makes the actor's own row bit-identical to the
lockstep implementation, which tests/test_sim.py asserts per round.

Protocol (two-phase Gauss-Seidel, bounded staleness S = `staleness`):

  * a head may start its round-k phase once every live neighbor's last
    applied round >= k-1-S; a tail once every live head neighbor reached
    round k-S (S=0 is the barriered schedule: tails consume the heads'
    fresh round-k hats, exactly the lockstep sweep),
  * after its phase the worker broadcasts one payload — quantized levels
    + (R, b) sideband, or the 1-bit censor flag — through sim.network,
  * the worker completes round k (per-edge dual update, snapshot, k+1)
    once its own phase is done and every live neighbor's applied round
    >= k-S.

Messages on one directed link are applied strictly in round order (the
channel is FIFO and the actor buffers anything early) because the
quantizer is delta-coded: reconstruction of round k+1 requires the hat
state after round k.  Dropped neighbors are detected via the network's
peer-down notification; the actor stops waiting on them and freezes the
shared edge's dual instead of integrating a stale residual forever.

Async duals (S > 0).  Mixing the worker's *current* hat with whatever
neighbor round happens to be applied makes the two endpoints of an edge
integrate different residuals and their dual mirrors drift apart — the
old behaviour froze such edges, which silences the duals entirely once
the schedule is latency-bound and shifts the fixed point.  Instead each
actor keeps an S-deep history of its own committed hat row and of every
neighbor's reconstructed row, and the round-k dual step uses the
*common round* k-S snapshot of both endpoints (the completion gate
guarantees round k-S is applied).  This is exactly the
``DistConfig.staleness`` pipeline's dual rule (dist.qgadmm._stale_round:
``hat_lag`` vs the S-stale slab), so a latency-bound async run and the
trainer's in-step pipeline share a fixed point.  S=0 keeps the original
fresh-edge mask path bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _set_row(tree, idx, row):
    """Functional row write: `tree` with stacked-dim row `idx` <- `row`."""
    return jax.tree.map(lambda a, r: a.at[idx].set(r.astype(a.dtype)),
                        tree, row)


@dataclasses.dataclass
class Msg:
    src: int
    rnd: int
    sent: bool          # False = censor flag only
    body: dict[str, Any]
    bits: float


class BaseActor:
    """Shared Gauss-Seidel protocol machine; numeric hooks in subclasses:

    _phase(key) -> (sent: bool, body: dict, payload_bits: float)
    _apply(j, msg) -> None           (fold a neighbor's payload in)
    _dual_update() -> None
    _snapshot() -> dict
    """

    def __init__(self, i, topo, *, engine, network, timeline, compute,
                 rounds, staleness=0, drop_round=None, seed=0, part=None):
        self.i = int(i)
        self.topo = topo
        self.engine = engine
        self.network = network
        self.timeline = timeline
        self.compute = compute
        self.rounds = int(rounds)
        self.staleness = int(staleness)
        self.drop_round = drop_round
        self.is_head = bool(topo.head_mask[self.i])
        self.neighbors = [int(j) for j in topo.neighbors(self.i)]
        self.rng = np.random.default_rng([seed, 3, self.i])
        # (rounds, N) bool participation schedule, or None = everyone every
        # round.  The schedule is agreed at setup (like the key beacon), so
        # every worker can advance a neighbor's round over its absent
        # rounds without a message.
        self.part = part

        self.rnd = 0
        self.phase_done = False
        self.computing = False
        self.dropped = False
        self.radio_busy = 0.0
        self.nbr_round = {j: -1 for j in self.neighbors}
        self.dead: set[int] = set()
        self._early: dict[int, dict[int, Msg]] = {j: {} for j in self.neighbors}
        self.sent_log: list[bool] = []

    # ------------------------------------------------------------ schedule --
    def start(self) -> None:
        for j in self.neighbors:
            self._advance_absent(j)
        self._try_phase()

    def _live(self):
        return (j for j in self.neighbors if j not in self.dead)

    def _participates(self, rnd: int, w: int | None = None) -> bool:
        if self.part is None:
            return True
        w = self.i if w is None else w
        return rnd >= self.rounds or bool(self.part[rnd, w])

    def _advance_absent(self, j: int) -> None:
        """Advance neighbor j's applied round over its scheduled absences
        (no message exists for those rounds; j's hat is unchanged there,
        which is exactly what _post_advance records for the lag history)."""
        while (self.nbr_round[j] + 1 < self.rounds
               and not self._participates(self.nbr_round[j] + 1, j)):
            self.nbr_round[j] += 1
            self._post_advance(j, self.nbr_round[j])

    def _phase_ready(self) -> bool:
        need = self.rnd - 1 - self.staleness if self.is_head \
            else self.rnd - self.staleness
        return all(self.nbr_round[j] >= need for j in self._live())

    def _complete_ready(self) -> bool:
        need = self.rnd - self.staleness
        return all(self.nbr_round[j] >= need for j in self._live())

    def _try_phase(self) -> None:
        if self.dropped or self.computing or self.phase_done \
                or self.rnd >= self.rounds:
            return
        # absent rounds (partial participation / pre-join): no compute, no
        # transmission, no dual — complete instantly.  Neighbors advance
        # over these rounds from the shared schedule (_advance_absent).
        while (self.rnd < self.rounds
               and not (self.drop_round is not None
                        and self.rnd >= self.drop_round)
               and not self._participates(self.rnd)):
            self.sent_log.append(False)
            self._skip_hook()
            self.timeline.record_round(self.i, self.rnd, self.engine.now)
            self.timeline.record_snapshot(self.i, self.rnd, self._snapshot())
            self.rnd += 1
            for j in self._live():
                self._drain(j)
        if self.rnd >= self.rounds:
            return
        if self.drop_round is not None and self.rnd >= self.drop_round:
            self.dropped = True
            self.network.announce_drop(self.i)
            return
        if not self._phase_ready():
            return
        self.computing = True
        t_start = max(self.engine.now, self.radio_busy)
        dt = self.compute.sample(self.i, self.rng)
        self.engine.at(t_start + dt, self._on_compute_done)

    def _on_compute_done(self) -> None:
        key = self._phase_key()
        sent, body, bits = self._phase(key)
        self.sent_log.append(bool(sent))
        msg = Msg(src=self.i, rnd=self.rnd, sent=bool(sent), body=body,
                  bits=float(bits))
        self.radio_busy = self.network.broadcast(self.i, float(bits), msg)
        self.computing = False
        self.phase_done = True
        self._try_complete()

    def _try_complete(self) -> None:
        if self.dropped or not self.phase_done or not self._complete_ready():
            return
        self._dual_update()
        self.timeline.record_round(self.i, self.rnd, self.engine.now)
        self.timeline.record_snapshot(self.i, self.rnd, self._snapshot())
        self.rnd += 1
        self.phase_done = False
        for j in self._live():
            self._drain(j)
        self._try_phase()

    # ------------------------------------------------------------ receiving --
    def on_message(self, msg: Msg) -> None:
        if self.dropped:
            return
        # delta-coded payloads apply strictly in round order; the FIFO
        # channel makes out-of-order arrival impossible, the buffer keeps
        # the invariant explicit (and guards any future transport).
        self._early[msg.src][msg.rnd] = msg
        self._drain(msg.src)
        self._try_phase()
        self._try_complete()

    def _drain(self, j: int) -> None:
        """Fold neighbor j's buffered payloads in, up to round rnd+S.

        The round-k dual must see round-k mirrors, so a payload for a
        FUTURE round stays buffered until this worker's own round catches
        up (drained again on every round advance).  Without partial
        participation the gate is a no-op — the barrier never lets a
        neighbor's round exceed rnd+S — but an absence schedule releases
        neighbors early (skip-advance), and their round-(k+1) payload
        must not commit into a mirror my round-k dual still reads."""
        while (self.nbr_round[j] + 1 in self._early[j]
               and self.nbr_round[j] + 1 <= self.rnd + self.staleness):
            m = self._early[j].pop(self.nbr_round[j] + 1)
            if m.sent:
                self._apply(j, m)
            self.nbr_round[j] += 1
            self._post_advance(j, m.rnd)
            self._advance_absent(j)

    def on_peer_down(self, j: int) -> None:
        if self.dropped or j in self.dead:
            return
        self.dead.add(int(j))
        self._peer_down_hook(int(j))
        self._try_phase()
        self._try_complete()

    # ---------------------------------------------------------------- hooks --
    def _phase_key(self):
        raise NotImplementedError

    def _phase(self, key):
        raise NotImplementedError

    def _apply(self, j: int, msg: Msg) -> None:
        raise NotImplementedError

    def _post_advance(self, j: int, rnd: int) -> None:
        """Called after neighbor j's round-`rnd` message is folded in
        (sent or censored) — subclasses record lag history here."""

    def _skip_hook(self) -> None:
        """Called when an absent round completes instantly — subclasses
        record the (unchanged) own-row lag history here."""

    def _dual_update(self) -> None:
        raise NotImplementedError

    def _snapshot(self) -> dict:
        raise NotImplementedError

    def _peer_down_hook(self, j: int) -> None:
        pass


class GraphActor(BaseActor):
    """Actor running core.gadmm.graph_phase on a local view.

    `fns` is the shared jitted function table built once by the runner
    (sim.runner._graph_fns): phase / apply / dual — one compilation for
    all N actors.
    """

    def __init__(self, i, topo, *, state0, fns, keys, cfg, payload_bits,
                 flag_bits, **kw):
        super().__init__(i, topo, **kw)
        self.fns = fns
        self.keys = keys          # (rounds, 2, key) beacon: [k][head?0:1]
        self.cfg = cfg
        self.payload_bits = float(payload_bits)
        self.flag_bits = float(flag_bits)
        self.theta = state0.theta
        self.hat = state0.theta_hat
        self.lam = state0.lam
        self.radius = state0.radius
        self.bits = state0.bits
        self.active = jnp.asarray(topo.head_mask if self.is_head
                                  else ~topo.head_mask)
        self.edge_alive = np.ones((topo.num_edges,), np.float32)
        self._edge_of = topo.edge_lookup(self.i)
        # S-deep lag histories for the async common-round dual (module
        # docstring): round -> committed own row / reconstructed nbr row
        self._own_hist: dict[int, Any] = {}
        self._nbr_hist: dict[int, dict[int, Any]] = \
            {j: {} for j in self.neighbors}

    def _phase_key(self):
        return self.keys[self.rnd][0 if self.is_head else 1]

    def _phase(self, key):
        (self.theta, self.hat, self.radius, self.bits,
         sent_i, qlev_i, hat_i, r_i, b_i) = self.fns["phase"](
            self.theta, self.hat, self.lam, self.radius, self.bits,
            self.active, key, jnp.asarray(self.rnd, jnp.int32), self.i)
        if not bool(sent_i):
            return False, {}, self.flag_bits
        # The wire carries (qlev, R, b) — that is what payload_bits prices
        # — and the receiver's dequantize_rows(qlev, hat_prev, R, b) is the
        # same arithmetic that committed hat_i on the sender.  The message
        # also transports the committed row itself: recomputing it in a
        # separately jitted program is NOT guaranteed bit-stable (XLA may
        # FMA-contract a*b+c differently per compilation), and the
        # keystone contract locks the sim to the lockstep reference
        # bit-for-bit.  tests/test_sim.py checks the codec roundtrip
        # against the shipped row.
        body = {"hat": hat_i, "qlev": qlev_i, "radius": r_i, "bits": b_i} \
            if self.cfg.quantize else {"hat": hat_i}
        return True, body, self.payload_bits

    def _apply(self, j, msg):
        self.hat = self.fns["apply"](self.hat, j, msg.body["hat"])

    def _post_advance(self, j, rnd):
        if self.staleness > 0:
            self._nbr_hist[j][rnd] = jax.tree.map(lambda a: a[j], self.hat)

    def _skip_hook(self):
        # absent round: own hat unchanged — record it so the round-(k-S)
        # common-round dual can look the lag snapshot up later
        if self.staleness > 0:
            self._own_hist[self.rnd] = jax.tree.map(lambda a: a[self.i],
                                                    self.hat)

    def _edge_mask(self) -> np.ndarray:
        """1.0 on live incident edges whose neighbor hat is round-fresh.

        Barriered (staleness 0) completion implies nbr_round[j] == rnd, so
        the mask is all-ones there (bit-parity preserved; x*1.0 is exact)
        and only drop-frozen edges are gated off.  An edge whose far
        endpoint sits this round out (partial participation / pre-join) is
        also frozen: the dual updates only when BOTH endpoints participate,
        so the two mirrors integrate identical increments."""
        mask = self.edge_alive.copy()
        for j, e in self._edge_of.items():
            if j not in self.dead and self.nbr_round[j] < self.rnd:
                mask[e] = 0.0
            if not self._participates(self.rnd, j):
                mask[e] = 0.0
        return mask

    def _dual_update(self):
        if self.staleness == 0:
            self.lam = self.fns["dual"](self.lam, self.hat,
                                        jnp.asarray(self._edge_mask()))
            return
        # async: dual step on the round-(k-S) common snapshot of both
        # endpoints (module docstring), gated off during the S fill rounds
        self._own_hist[self.rnd] = jax.tree.map(lambda a: a[self.i],
                                                self.hat)
        lag = self.rnd - self.staleness
        if lag >= 0:
            hat_sub = _set_row(self.hat, self.i, self._own_hist[lag])
            mask = self.edge_alive.copy()
            for j, e in self._edge_of.items():
                row = self._nbr_hist[j].get(lag)
                if row is None:        # dead before round `lag` — frozen
                    mask[e] = 0.0
                else:
                    hat_sub = _set_row(hat_sub, j, row)
                if not self._participates(self.rnd, j):
                    mask[e] = 0.0      # both-endpoints participation rule
            self.lam = self.fns["dual"](self.lam, hat_sub,
                                        jnp.asarray(mask))
        for h in (self._own_hist, *self._nbr_hist.values()):
            for r in [r for r in h if r < self.rnd - self.staleness]:
                del h[r]

    def _peer_down_hook(self, j):
        e = self._edge_of.get(j)
        if e is not None:
            self.edge_alive[e] = 0.0

    def _snapshot(self):
        lam_rows = {self._edge_of[j]: np.asarray(self.lam[self._edge_of[j]])
                    for j in self.neighbors
                    if int(self.topo.edges[self._edge_of[j], 0]) == self.i}
        return dict(theta=np.asarray(self.theta[self.i]),
                    hat=np.asarray(self.hat[self.i]),
                    radius=np.asarray(self.radius[self.i]),
                    bits=np.asarray(self.bits[self.i]),
                    sent=self.sent_log[-1], lam_rows=lam_rows)


class TrainerActor(BaseActor):
    """Actor replaying QGADMMTrainer's unsharded reference step pieces.

    The local view is the trainer's full stacked 9-tuple state; `fns`
    (sim.runner._trainer_fns) wraps the trainer's phase_compute /
    phase_apply / dual_update methods, jitted once for all actors.
    """

    def __init__(self, i, topo, *, st0, batch, fns, keys, trainer,
                 payload_bits, flag_bits, **kw):
        super().__init__(i, topo, **kw)
        self.st = st0
        self.batch = batch
        self.fns = fns
        self.keys = keys
        self.trainer = trainer
        self.payload_bits = float(payload_bits)
        self.flag_bits = float(flag_bits)
        self.quantize = trainer.dcfg.gadmm.quantize
        self.active = jnp.asarray(topo.head_mask if self.is_head
                                  else ~topo.head_mask)
        # neighbor j -> the directed slab row with dst=i that stores what i
        # knows about j (the trainer's edge-indexed state layout)
        self.eidx = trainer.eidx
        self._in_edge = self.eidx.in_edges(self.i)
        self.edge_alive = np.ones((self.eidx.num_directed,), np.float32)
        # S-deep lag histories for the async common-round dual (module
        # docstring): round -> committed own hat row / reconstructed slab row
        self._own_hist: dict[int, Any] = {}
        self._nbr_hist: dict[int, dict[int, Any]] = \
            {j: {} for j in self.neighbors}

    def _phase_key(self):
        return self.keys[self.rnd][0 if self.is_head else 1]

    def _phase(self, key):
        self.st, sent_i, hat_row, wire_i, r_i, b_i = self.fns["phase"](
            self.st, self.batch, self.active, key,
            jnp.asarray(self.rnd, jnp.int32), self.i)
        if not bool(sent_i):
            return False, {}, self.flag_bits
        # wire_i/(R, b) are the billed wire content; hat_row is the
        # committed reconstruction the receivers store (see GraphActor:
        # cross-program recompute is not FMA-stable, and the trainer's
        # in-program receiver path is bit-identical to the sender's commit
        # — checked by the sim-vs-trainer parity suite).
        body = {"hat": hat_row, "wire": wire_i}
        if self.quantize:
            body["radius"] = r_i
            body["bits"] = b_i
        return True, body, self.payload_bits

    def _apply(self, j, msg):
        self.st = self.fns["apply"](
            self.st, jnp.asarray(self._in_edge[j], jnp.int32),
            msg.body["hat"])

    def _post_advance(self, j, rnd):
        if self.staleness > 0:
            d = self._in_edge[j]
            self._nbr_hist[j][rnd] = jax.tree.map(lambda a: a[d],
                                                  self.st[2])

    def _dual_update(self):
        mask = self.edge_alive.copy()
        if self.staleness == 0:
            # same fresh-edge gating as GraphActor._edge_mask, on the
            # directed slab rows with dst=i (the other rows of the local
            # view are don't-care)
            for j, d in self._in_edge.items():
                if j not in self.dead and self.nbr_round[j] < self.rnd:
                    mask[d] = 0.0
            self.st = self.fns["dual"](self.st, jnp.asarray(mask))
            return
        # async: splice the round-(k-S) common snapshot (own hat row +
        # in-edge slab rows) into a scratch state, take the dual step
        # there, and keep only its lam_edge — this is the trainer
        # pipeline's `hat_lag` dual rule (dist.qgadmm._stale_round)
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = self.st
        self._own_hist[self.rnd] = jax.tree.map(lambda a: a[self.i], hat)
        lag = self.rnd - self.staleness
        if lag >= 0:
            hat_sub = _set_row(hat, self.i, self._own_hist[lag])
            hat_edge_sub = hat_edge
            for j, d in self._in_edge.items():
                row = self._nbr_hist[j].get(lag)
                if row is None:        # dead before round `lag` — frozen
                    mask[d] = 0.0
                else:
                    hat_edge_sub = _set_row(hat_edge_sub, d, row)
            st_sub = (theta, hat_sub, hat_edge_sub, lam_edge, radius,
                      bits, mu, nu, t)
            lam_edge = self.fns["dual"](st_sub, jnp.asarray(mask))[3]
            self.st = (theta, hat, hat_edge, lam_edge, radius, bits,
                       mu, nu, t)
        for h in (self._own_hist, *self._nbr_hist.values()):
            for r in [r for r in h if r < self.rnd - self.staleness]:
                del h[r]

    def _peer_down_hook(self, j):
        self.edge_alive = self.edge_alive.copy()
        self.edge_alive[self._in_edge[j]] = 0.0

    def _snapshot(self):
        import jax
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = self.st
        row = lambda tree: jax.tree.map(
            lambda a: np.asarray(a[self.i]), tree)

        def port_row(slab, c):
            # slab row with dst=i and color c, or the zeros a missing port
            # always held in the port-dense layout
            s = int(self.eidx.slot[self.i, c])
            if s < 0:
                return jax.tree.map(
                    lambda a: np.asarray(jnp.zeros_like(a[0])), slab)
            return jax.tree.map(lambda a: np.asarray(a[s]), slab)

        ports = self.topo.num_ports
        return dict(theta=row(theta), hat=row(hat),
                    hat_nbr=tuple(port_row(hat_edge, c)
                                  for c in range(ports)),
                    lam_nbr=tuple(port_row(lam_edge, c)
                                  for c in range(ports)),
                    radius=np.asarray(radius[self.i]),
                    bits=np.asarray(bits[self.i]),
                    sent=self.sent_log[-1])
