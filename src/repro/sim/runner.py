"""Entry points of the event-driven Q-GADMM runtime.

``simulate(xs, ys, gcfg, scfg)`` plays the CQ-GGADMM graph reference
(core.gadmm.graph_phase math) out message-by-message for a linear
regression problem; ``simulate_trainer(model, cfg, dcfg, batch, scfg)``
does the same for the distributed trainer's unsharded reference step
(dist.qgadmm.QGADMMTrainer).  Both build one shared jit-compiled function
table (one compilation serves all N actors), wire the actors to the
engine/network/timeline, run the event loop to quiescence, and return a
:class:`SimResult` with per-round assembled states (for the bit-parity
tests), an objective trace, and the timeline's wall-clock/Joules
accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gadmm, quantizer
from repro.core.censor import FLAG_BITS, CensorConfig
from repro.core.comm_model import RadioConfig
from repro.core.topology import (DENSE_PLACEMENT_MAX, Placement, Topology,
                                 build_topology)

from .engine import Engine
from .network import ComputeModel, FaultPlan, Network, NetworkConfig
from .timeline import Timeline
from .worker import GraphActor, TrainerActor


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Scenario description for one simulator run.

    topology:  core.topology kind name or explicit Topology.
    rounds:    GADMM rounds each worker attempts to complete.
    staleness: 0 = barriered (bit-identical to the lockstep references
               under an ideal network); S > 0 allows every worker to run
               up to S rounds ahead of its slowest neighbor, computing
               against the freshest hats it has (bounded-staleness async).
    seed:      placement positions + every channel/compute draw.
    participation: per-(round, worker) Bernoulli rate of taking part in a
               round (1.0 = everyone, the default).  The schedule is drawn
               once at setup from default_rng([seed, 13]) and shared by
               every worker — an absent worker skips compute/transmit/dual
               for that round (it still listens), neighbors advance over
               its absence without a message, and an edge's dual updates
               only when BOTH endpoints participate.
    engine:    'events' = the per-message event loop (the bitwise oracle);
               'vectorized' = the large-N fast path (sim.vectorized) —
               identical states, batched timing (graph mode, staleness 0).
    """

    topology: Any = "chain"
    rounds: int = 100
    staleness: int = 0
    seed: int = 0
    radio: RadioConfig = RadioConfig()
    network: NetworkConfig = NetworkConfig()
    compute: ComputeModel = ComputeModel()
    faults: FaultPlan = FaultPlan()
    record_states: bool = True
    max_events: int | None = None
    participation: float = 1.0
    engine: str = "events"

    def __post_init__(self):
        assert self.engine in ("events", "vectorized"), self.engine
        assert 0.0 < self.participation <= 1.0, self.participation

    def event_budget(self, topo: Topology) -> int:
        """Liveness budget for Engine.run, scaled by the scenario.

        Per round the loop fires <= N compute completions + 2E deliveries
        (+1 for the odd engine bookkeeping event); retransmissions
        serialize inside a delivery's schedule and add no events, but
        lossy runs get extra slack for the bounded-retransmit tail.
        Membership churn (drops with their peer-down notifications, late
        joins) adds a per-worker term on top.
        """
        if self.max_events is not None:
            return self.max_events
        per_round = topo.n + 2 * topo.num_edges + 1
        slack = 10
        if self.network.loss_prob > 0.0:
            slack += 2 + min(self.network.max_retransmits, 100) // 10
        churn = sum(int(topo.degree[int(w)]) + 1
                    for w in self.faults.drop_round)
        churn += 2 * len(self.faults.join_round)
        return slack * (self.rounds + 1) * per_round + 16 * churn + 1000


@dataclasses.dataclass
class SimResult:
    topo: Topology
    timeline: Timeline
    states: list[Any]           # per-round assembled states (or [])
    losses: np.ndarray          # |F(theta_k) - F*| per assembled round
    events: int
    fstar: float | None = None  # |F*| of the problem (graph mode only)

    def to_target(self, target: float) -> dict[str, float]:
        return self.timeline.to_target(list(self.losses), target)

    def to_rel_target(self, rel: float) -> dict[str, float]:
        """*-to-target at a RELATIVE objective gap (needs fstar)."""
        assert self.fstar is not None, "relative targets need graph mode"
        return self.to_target(rel * self.fstar)

    def final_rel_gap(self) -> float:
        assert self.fstar is not None and len(self.losses)
        return float(self.losses[-1]) / self.fstar

    def summary(self) -> dict:
        s = self.timeline.summary()
        s["events"] = self.events
        if len(self.losses):
            s["final_gap"] = float(self.losses[-1])
        return s


def grid_placement(n: int, seed: int, topo: Topology,
                   grid: float = 250.0) -> Placement:
    """The paper's uniform grid drop, carrying an externally built
    Topology (random_placement derives its own graph from the
    nearest-neighbor chain order, which is NOT the canonical
    build_topology graph the lockstep references use — parity needs the
    exact same Topology on both sides)."""
    rng = np.random.default_rng([seed, 11])
    pos = rng.uniform(0.0, grid, size=(n, 2))
    if n > DENSE_PLACEMENT_MAX:
        # large-N path: the full O(N^2) pairwise matrix is exactly what
        # the scale refactor removed — the PS pick degrades to
        # centroid-nearest (the sim never uses the PS baseline anyway)
        ps = int(np.argmin(np.linalg.norm(pos - pos.mean(axis=0), axis=1)))
        ps_dist = np.linalg.norm(pos - pos[ps], axis=1)
    else:
        dmat = np.linalg.norm(pos[None, :, :] - pos[:, None, :], axis=-1)
        ps = int(np.argmin(dmat.sum(axis=1)))
        ps_dist = dmat[ps]
    return Placement(
        positions=pos, chain=np.arange(n), ps_index=ps,
        chain_hop_dist=np.linalg.norm(pos[1:] - pos[:-1], axis=1),
        ps_dist=ps_dist, topology=topo)


def participation_schedule(scfg: SimConfig, n: int) -> np.ndarray | None:
    """(rounds, N) bool participation mask shared by both engines, or
    None when everyone participates every round.

    Bernoulli(participation) per (round, worker) from
    default_rng([seed, 13]) — a setup-time agreement like the key beacon,
    so each worker advances its neighbors over absent rounds without a
    message — AND'ed with the FaultPlan's arrival schedule (a worker that
    joins at round r sits out rounds 0..r-1)."""
    joins = scfg.faults.join_round
    if scfg.participation >= 1.0 and not joins:
        return None
    part = np.ones((scfg.rounds, n), bool)
    if scfg.participation < 1.0:
        rng = np.random.default_rng([scfg.seed, 13])
        part &= rng.uniform(size=(scfg.rounds, n)) < scfg.participation
    for w, r in joins.items():
        part[:int(r), int(w)] = False
    return part


def _beacon(key, rounds: int):
    """Precomputed per-round (head, tail) phase keys — the same split
    chain graph_step / the trainer step walk (a deterministic seed
    schedule every worker agreed on at setup; only senders consume it)."""
    keys = []
    for _ in range(rounds):
        key, k_h, k_t = jax.random.split(key, 3)
        keys.append((k_h, k_t))
    return keys


# ------------------------------------------------------------- graph mode --
def _graph_fns(q, cfg, tc, censor):
    """Shared jitted function table for GraphActor (one compile, N actors)."""

    @jax.jit
    def phase(theta, hat, lam, radius, bits, active, key, step, i):
        th, h, r, b, sent, qlev = gadmm.graph_phase(
            theta, hat, lam, radius, bits, active, key,
            q=q, cfg=cfg, tc=tc, step=step, censor=censor)
        return th, h, r, b, sent[i], qlev[i], h[i], r[i], b[i]

    @jax.jit
    def apply(hat, j, row):
        return hat.at[j].set(row)

    @jax.jit
    def dual(lam, hat, edge_mask):
        return gadmm.graph_dual_update(lam, hat, cfg, tc, edge_mask)

    @jax.jit
    def phase_full(theta, hat, lam, radius, bits, active, key, step):
        """Whole-phase update for the vectorized engine: one call per
        color group per round (active = phase mask & participation mask)
        instead of one per actor — graph_phase leaves inactive rows
        untouched, so the result is bitwise the actors' per-row commits."""
        return gadmm.graph_phase(theta, hat, lam, radius, bits, active, key,
                                 q=q, cfg=cfg, tc=tc, step=step,
                                 censor=censor)

    return {"phase": phase, "apply": apply, "dual": dual,
            "phase_full": phase_full}


def _build_world(scfg: SimConfig, topo: Topology, placement):
    engine = Engine()
    timeline = Timeline(topo.n)
    placement = placement or grid_placement(topo.n, scfg.seed, topo)
    network = Network(engine, topo, placement, scfg.radio, scfg.network,
                      timeline, seed=scfg.seed)
    return engine, timeline, network


def _run_world(engine, network, actors, scfg: SimConfig, topo: Topology):
    network.register(actors)
    for a in actors:
        a.start()
    events = engine.run(max_events=scfg.event_budget(topo))
    # a drained queue with unfinished live workers = protocol deadlock
    for a in actors:
        assert a.dropped or a.rnd >= scfg.rounds, (
            f"deadlock: worker {a.i} stuck at round {a.rnd}/{scfg.rounds} "
            f"(phase_done={a.phase_done}, nbr_round={a.nbr_round})")
    return events


def _assemble_graph_states(timeline: Timeline, state0, topo: Topology):
    """Stack per-worker snapshots into per-round GraphState-like views.
    Dropped workers contribute their last snapshot (frozen state)."""
    n = topo.n
    last = {w: None for w in range(n)}
    alive = [w for w in range(n) if w not in timeline.dropped_at]
    counted = alive if alive else list(range(n))
    k_max = min((len(timeline.round_done[w]) for w in counted), default=0)
    out = []
    for k in range(k_max):
        theta = np.asarray(state0.theta).copy()
        hat = np.asarray(state0.theta_hat).copy()
        lam = np.asarray(state0.lam).copy()
        radius = np.asarray(state0.radius).copy()
        bits = np.asarray(state0.bits).copy()
        sent = np.zeros((n,), bool)
        for w in range(n):
            snap = timeline.snapshots.get(k, {}).get(w, last[w])
            if snap is None:
                continue
            last[w] = snap
            theta[w] = snap["theta"]
            hat[w] = snap["hat"]
            radius[w] = snap["radius"]
            bits[w] = snap["bits"]
            sent[w] = snap["sent"]
            for e, row in snap["lam_rows"].items():
                lam[e] = row
        out.append(dict(theta=theta, theta_hat=hat, lam=lam, radius=radius,
                        bits=bits, sent=sent))
    return out


def simulate(xs, ys, gcfg: gadmm.GADMMConfig, scfg: SimConfig,
             censor: CensorConfig | None = None,
             placement: Placement | None = None) -> SimResult:
    """Event-driven CQ-GGADMM on per-worker quadratics (xs: (N, m, d),
    ys: (N, m)), reusing core.gadmm.graph_phase math actor-by-actor."""
    assert gcfg.topk_frac >= 1.0, \
        "top-k sparsification is not supported by the simulator"
    if scfg.engine == "vectorized":
        from .vectorized import simulate_vectorized
        return simulate_vectorized(xs, ys, gcfg, scfg, censor=censor,
                                   placement=placement)
    n, _, d = xs.shape
    topo = build_topology(scfg.topology, n)
    q = gadmm.make_graph_quadratic(xs, ys, gcfg.rho, topo)
    tc = gadmm.graph_consts(topo)
    state0 = gadmm.graph_init_state(topo, d, gcfg, seed=scfg.seed)
    fns = _graph_fns(q, gcfg, tc, censor)
    keys = _beacon(state0.key, scfg.rounds)
    payload_bits = gadmm._payload_bits_per_worker(gcfg, d)
    part = participation_schedule(scfg, n)

    engine, timeline, network = _build_world(scfg, topo, placement)
    actors = [
        GraphActor(
            i, topo, state0=state0, fns=fns, keys=keys, cfg=gcfg,
            payload_bits=payload_bits, flag_bits=FLAG_BITS,
            engine=engine, network=network, timeline=timeline,
            compute=scfg.compute, rounds=scfg.rounds,
            staleness=scfg.staleness, part=part,
            drop_round=scfg.faults.drops_at(i), seed=scfg.seed)
        for i in range(n)
    ]
    events = _run_world(engine, network, actors, scfg, topo)

    states = _assemble_graph_states(timeline, state0, topo) \
        if scfg.record_states else []
    fstar = _graph_fstar(q, xs, ys, d)
    if states:
        losses = np.asarray([abs(float(q.objective(jnp.asarray(s["theta"])))
                                 - fstar) for s in states])
    else:
        losses = np.zeros((0,))
    return SimResult(topo=topo, timeline=timeline, states=states,
                     losses=losses, events=events, fstar=abs(fstar))


def _graph_fstar(q, xs, ys, d: int) -> float:
    xtx = jnp.sum(q.xtx, axis=0)
    xty = jnp.sum(q.xty, axis=0)
    theta_star = jnp.linalg.solve(xtx, xty)
    n = q.xty.shape[0]
    return float(q.objective(jnp.broadcast_to(theta_star, (n, d))))


# ----------------------------------------------------------- trainer mode --
def _trainer_fns(trainer):
    """Shared jitted wrappers over the trainer's reference step pieces."""
    quantize = trainer.dcfg.gadmm.quantize

    @jax.jit
    def phase(st, batch, active, key, step, i):
        st2, payload, _ = trainer.phase_compute(st, batch, active, key, step)
        hat_row = jax.tree.map(lambda a: a[i], st2[1])
        if quantize:
            return (st2, payload["sent"][i], hat_row, payload["wire"][i],
                    payload["radius"][i], payload["bits"][i])
        return (st2, payload["sent"][i], hat_row, payload["wire"][i],
                jnp.zeros(()), jnp.zeros((), jnp.int32))

    @jax.jit
    def apply(st, d, row):
        """Store the partner's committed hat row at directed slab row d
        (the value the reference's in-program phase_apply reconstructs
        bit-identically; see TrainerActor._phase)."""
        (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t) = st
        hat_edge = jax.tree.map(lambda a, r: a.at[d].set(r.astype(a.dtype)),
                                hat_edge, row)
        return (theta, hat, hat_edge, lam_edge, radius, bits, mu, nu, t)

    @jax.jit
    def dual(st, edge_mask):
        return trainer.dual_update(st, edge_mask)

    return {"phase": phase, "apply": apply, "dual": dual}


def trainer_link_bits(trainer, d: int) -> float:
    """Per-directed-link payload bits, matching
    QGADMMTrainer.wire_bits_per_round's per-link term."""
    row_bits = 8 * trainer.wire_row_bytes(d)
    if trainer.dcfg.gadmm.quantize:
        n_r = (len(jax.tree.leaves(trainer.model.init(
            jax.random.PRNGKey(0), trainer.cfg)))
            if trainer.dcfg.radius_mode == "per_tensor" else 1)
        return row_bits + quantizer.header_bits(num_radii=n_r)
    return row_bits


def simulate_trainer(trainer, state0, batch, scfg: SimConfig,
                     placement: Placement | None = None) -> SimResult:
    """Event-driven replay of QGADMMTrainer's unsharded reference step.

    trainer: a QGADMMTrainer (gauss-seidel, overlap=False); its
    DistConfig.topology must equal scfg.topology.  state0: a DistState
    from dist.qgadmm.init_state.  The actors replay phase_compute /
    phase_apply / dual_update row-by-row; under an ideal network the
    per-round rows are bit-identical to make_train_step()
    (tests/test_sim.py)."""
    dcfg = trainer.dcfg
    assert dcfg.mode == "gauss-seidel" and not dcfg.overlap, \
        "the simulator models the two-phase gauss-seidel schedule"
    assert dcfg.staleness == 0, \
        "pass staleness via SimConfig: the simulator's per-message async " \
        "schedule subsumes the trainer's in-step pipeline"
    assert scfg.engine == "events", \
        "the vectorized engine covers graph mode only"
    assert scfg.participation >= 1.0 and not scfg.faults.join_round, \
        "partial participation in trainer mode lives in " \
        "DistConfig.participation (the in-step fold-in masks), not the sim"
    topo = trainer.topo
    assert build_topology(scfg.topology, dcfg.num_workers).kind == topo.kind
    d = sum(int(np.prod(l.shape[1:]))
            for l in jax.tree.leaves(state0.theta))
    fns = _trainer_fns(trainer)
    keys = _beacon(state0.key, scfg.rounds)
    st0 = (state0.theta, state0.theta_hat, state0.hat_edge, state0.lam_edge,
           state0.radius, state0.bits, state0.opt_mu, state0.opt_nu,
           state0.opt_t)

    engine, timeline, network = _build_world(scfg, topo, placement)
    actors = [
        TrainerActor(
            i, topo, st0=st0, batch=batch, fns=fns, keys=keys,
            trainer=trainer, payload_bits=trainer_link_bits(trainer, d),
            flag_bits=FLAG_BITS, engine=engine, network=network,
            timeline=timeline, compute=scfg.compute, rounds=scfg.rounds,
            staleness=scfg.staleness, drop_round=scfg.faults.drops_at(i),
            seed=scfg.seed)
        for i in range(dcfg.num_workers)
    ]
    events = _run_world(engine, network, actors, scfg, topo)
    states = []
    if scfg.record_states:
        k_max = min((len(timeline.round_done[w])
                     for w in range(dcfg.num_workers)), default=0)
        states = [
            {w: timeline.snapshots[k][w] for w in range(dcfg.num_workers)
             if w in timeline.snapshots.get(k, {})}
            for k in range(k_max)
        ]
    return SimResult(topo=topo, timeline=timeline, states=states,
                     losses=np.zeros((0,)), events=events)
