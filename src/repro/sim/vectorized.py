"""Vectorized large-N fast path of the Q-GADMM simulator.

The event loop (sim.runner.simulate, ``engine='events'``) is one Python
callback per message — perfect as a bitwise protocol oracle, hopeless at
N=10^4.  This module replays the SAME protocol as R rounds of whole-graph
array operations: one jitted ``graph_phase`` call per color group (the
row-local update leaves inactive rows untouched, so a single masked call
commits exactly the rows the actors would), one ``graph_dual_update``
per round, and a numpy timing recurrence that batches every phase-group
transmission wave into O(E) segment ops instead of O(E) heap events.

Timing recurrence (per round k, matching the actors' gates):

  head start   = max(own prev completion, radio-free, newest arrival on
                 each tail->head link)           [the k-1 freshness gate]
  tail start   = max(own prev completion, radio-free, newest arrival on
                 each head->tail link)           [the fresh round-k gate]
  completion   = tails: own phase end; heads: max(phase end, newest
                 tail->head arrival after the tail wave)
  absent round = completes instantly at the previous completion time
                 (partial participation / pre-join, exactly the event
                 loop's skip path)

Each transmission wave prices a broadcast slot per present sender, then
serializes loss retransmits (or unicast per-neighbor slots) in the same
per-sender port order the event loop walks, with per-directed-link FIFO
floors.

Parity contract (locked by tests/test_sim.py):

  * per-round worker STATES are bit-identical to the event loop always —
    both engines run the identical jitted row math over the identical
    participation schedule (sim.runner.participation_schedule), and
    bounded retransmit means channel draws never change which payloads
    commit;
  * wall-clock/energy accounting is bit-identical for
    transport='broadcast' with loss_prob=0 and zero jitter (stragglers,
    latency, participation, joins included);
  * under loss/jitter/unicast the channel draws come from dedicated
    batched streams (default_rng([seed, 17]) for attempts+jitter,
    [seed, 19] for compute jitter), so timing agrees with the event
    loop in distribution, not draw-for-draw.

Scope: graph mode only, staleness 0, no mid-run drops — membership churn
is expressed as arrivals/participation schedules (FaultPlan.join_round,
SimConfig.participation).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import gadmm
from repro.core.censor import FLAG_BITS, CensorConfig
from repro.core.comm_model import tx_energy
from repro.core.topology import Placement, build_topology

from .timeline import ArrayTimeline


def simulate_vectorized(xs, ys, gcfg: gadmm.GADMMConfig, scfg,
                        censor: CensorConfig | None = None,
                        placement: Placement | None = None):
    from .runner import (SimResult, _beacon, _graph_fns, _graph_fstar,
                         grid_placement, participation_schedule)

    assert scfg.staleness == 0, \
        "the vectorized engine models the barriered (staleness 0) schedule"
    assert not scfg.faults.drop_round, \
        "the vectorized engine has no link-layer drop detection; model " \
        "churn as participation / join_round schedules"
    n, _, d = xs.shape
    topo = build_topology(scfg.topology, n)
    q = gadmm.make_graph_quadratic(xs, ys, gcfg.rho, topo)
    tc = gadmm.graph_consts(topo)
    state0 = gadmm.graph_init_state(topo, d, gcfg, seed=scfg.seed)
    fns = _graph_fns(q, gcfg, tc, censor)
    keys = _beacon(state0.key, scfg.rounds)
    payload_bits = float(gadmm._payload_bits_per_worker(gcfg, d))
    part = participation_schedule(scfg, n)
    placement = placement or grid_placement(n, scfg.seed, topo)

    head = topo.head_mask
    radio, ncfg, compute = scfg.radio, scfg.network, scfg.compute
    slot = radio.slot_s
    rounds = scfg.rounds
    heads_ct = int(head.sum())
    group = np.where(head, max(heads_ct, 1), max(n - heads_ct, 1))
    bw = radio.total_bandwidth_hz / group.astype(float)
    bcast_d = placement.broadcast_dist()
    factors = np.asarray([compute.factor(w) for w in range(n)])

    # directed out-edges in each worker's port order — the exact neighbor
    # iteration/serialization order of Network.broadcast
    pflat = topo.port.ravel()
    pmask = pflat >= 0
    pe_src = np.repeat(np.arange(n), topo.num_ports)[pmask]
    pe_dst = pflat[pmask]
    ld: dict[tuple[int, int], float] = {}
    for (u, v), dist in zip(topo.edges.tolist(),
                            placement.edge_dists().tolist()):
        ld[(u, v)] = ld[(v, u)] = float(dist)
    pe_dist = np.asarray([ld[(int(s), int(t))]
                          for s, t in zip(pe_src, pe_dst)])

    def _phase_edges(src_is_head: bool) -> dict:
        idx = np.flatnonzero(head[pe_src] == src_is_head)
        src = pe_src[idx]
        first = np.ones(len(idx), bool)
        first[1:] = src[1:] != src[:-1]
        return dict(idx=idx, src=src, dst=pe_dst[idx], dist=pe_dist[idx],
                    gidx=np.cumsum(first) - 1,
                    firstpos=np.flatnonzero(first))

    ph_h, ph_t = _phase_edges(True), _phase_edges(False)

    def _gcumsum(vals: np.ndarray, ph: dict) -> np.ndarray:
        """Inclusive cumulative sum within each sender's edge group."""
        c = np.cumsum(vals)
        base = c[ph["firstpos"]] - vals[ph["firstpos"]]
        return c - base[ph["gidx"]] if len(c) else c

    rng_ch = np.random.default_rng([scfg.seed, 17])
    rng_cp = np.random.default_rng([scfg.seed, 19])

    fifo = np.zeros(len(pe_src))            # per directed edge (pe order)
    last_arr = np.full(len(pe_src), -np.inf)
    radio_busy = np.zeros(n)
    t_done = np.zeros(n)
    tx_t, tx_src, tx_bits, tx_e, tx_att = [], [], [], [], []
    tx_dst, tx_rnd = [], []
    cur_round = [0]     # mutable holder: the round loop advances it

    def _record(t, srcs, b, dist, attempt, dst=None):
        tx_t.append(t)
        tx_src.append(srcs)
        tx_bits.append(b)
        tx_e.append(tx_energy(b, dist, bw[srcs], slot, radio.noise_psd))
        tx_att.append(attempt)
        tx_dst.append(np.full(len(srcs), -1, np.int64) if dst is None
                      else np.asarray(dst, np.int64))
        tx_rnd.append(np.full(len(srcs), cur_round[0], np.int64))

    def _spread(reps):
        """0..reps[i]-1 counters, flattened per segment."""
        flat = np.repeat(np.arange(len(reps)), reps)
        intra = np.arange(int(reps.sum())) \
            - np.repeat(np.cumsum(reps) - reps, reps)
        return flat, intra

    def _wave(ph, Td, present, bits_w):
        """One phase-group transmission wave: records transmissions,
        advances the phase edges' FIFO floors / newest-arrival clocks,
        returns the senders' radio-free times (meaningful where
        `present`)."""
        m = len(ph["src"])
        sel = present[ph["src"]]
        if ncfg.loss_prob > 0.0:
            att = np.minimum(rng_ch.geometric(1.0 - ncfg.loss_prob, m),
                             ncfg.max_retransmits + 1)
        else:
            att = np.ones(m, np.int64)
        jit = (rng_ch.uniform(0.0, ncfg.jitter_s, m)
               if ncfg.jitter_s > 0.0 else np.zeros(m))
        psrc = ph["src"]
        if ncfg.transport == "broadcast":
            sidx = np.flatnonzero(present)
            _record(Td[sidx], sidx, bits_w[sidx], bcast_d[sidx],
                    np.zeros(len(sidx), np.int64))
            retx = np.where(sel, att - 1, 0)
            cum = _gcumsum(retx.astype(float) * slot, ph)
            ready = Td[psrc] + slot + np.where(retx > 0, cum, 0.0)
            free = Td + slot \
                + np.bincount(psrc, weights=retx * slot, minlength=n)
            late = np.flatnonzero(retx > 0)
            if len(late):
                reps = retx[late]
                base = Td[psrc[late]] + slot + (cum[late] - reps * slot)
                flat, intra = _spread(reps)
                srcs = psrc[late][flat]
                _record(base[flat] + intra * slot, srcs, bits_w[srcs],
                        ph["dist"][late][flat],
                        (intra + 1).astype(np.int64),
                        dst=ph["dst"][late][flat])
        else:
            a_eff = np.where(sel, att, 0)
            cum = _gcumsum(a_eff.astype(float) * slot, ph)
            ready = Td[psrc] + cum
            free = Td + np.bincount(psrc, weights=a_eff * slot, minlength=n)
            act = np.flatnonzero(sel)
            if len(act):
                reps = a_eff[act]
                base = Td[psrc[act]] + (cum[act] - reps * slot)
                flat, intra = _spread(reps)
                srcs = psrc[act][flat]
                _record(base[flat] + intra * slot, srcs, bits_w[srcs],
                        ph["dist"][act][flat], intra.astype(np.int64),
                        dst=ph["dst"][act][flat])
        arr = np.maximum(ready + ncfg.latency_s + jit, fifo[ph["idx"]])
        fifo[ph["idx"]] = np.where(sel, arr, fifo[ph["idx"]])
        last_arr[ph["idx"]] = np.where(sel, arr, last_arr[ph["idx"]])
        return free

    def _inmax(ph):
        """Per-worker newest arrival over the phase's directed in-edges
        (-inf where a link never delivered)."""
        out = np.full(n, -np.inf)
        if len(ph["idx"]):
            np.maximum.at(out, ph["dst"], last_arr[ph["idx"]])
        return out

    e_head = topo.edges[:, 0]
    e_tail = topo.edges[:, 1]
    ones_mask = np.ones(topo.num_edges, np.float32)
    theta, hat, lam = state0.theta, state0.theta_hat, state0.lam
    radius, bits_st = state0.radius, state0.bits
    round_done = np.zeros((rounds, n))
    states: list[dict] = []
    objs: list[float] = []

    for k in range(rounds):
        cur_round[0] = k
        part_k = np.ones(n, bool) if part is None else part[k]
        pres_h = head & part_k
        pres_t = ~head & part_k
        dt = compute.base_s * factors
        if compute.jitter_sigma > 0.0:
            dt = dt * rng_cp.lognormal(0.0, compute.jitter_sigma, n)
        step = jnp.asarray(k, jnp.int32)
        k_h, k_t = keys[k]

        start_h = np.maximum(np.maximum(t_done, radio_busy), _inmax(ph_t))
        td_h = start_h + dt
        theta, hat, radius, bits_st, sent_h, _ = fns["phase_full"](
            theta, hat, lam, radius, bits_st, jnp.asarray(pres_h), k_h,
            step)
        sent_h = np.asarray(sent_h)
        free = _wave(ph_h, td_h, pres_h,
                     np.where(sent_h, payload_bits, float(FLAG_BITS)))
        radio_busy = np.where(pres_h, free, radio_busy)

        start_t = np.maximum(np.maximum(t_done, radio_busy), _inmax(ph_h))
        td_t = start_t + dt
        theta, hat, radius, bits_st, sent_t, _ = fns["phase_full"](
            theta, hat, lam, radius, bits_st, jnp.asarray(pres_t), k_t,
            step)
        sent_t = np.asarray(sent_t)
        free = _wave(ph_t, td_t, pres_t,
                     np.where(sent_t, payload_bits, float(FLAG_BITS)))
        radio_busy = np.where(pres_t, free, radio_busy)

        if topo.num_edges:
            em = ones_mask if part is None \
                else (part_k[e_head] & part_k[e_tail]).astype(np.float32)
            lam = fns["dual"](lam, hat, jnp.asarray(em))

        t_done = np.where(pres_h, np.maximum(td_h, _inmax(ph_t)),
                          np.where(pres_t, td_t, t_done))
        round_done[k] = t_done
        objs.append(float(q.objective(theta)))
        if scfg.record_states:
            states.append(dict(
                theta=np.asarray(theta), theta_hat=np.asarray(hat),
                lam=np.asarray(lam), radius=np.asarray(radius),
                bits=np.asarray(bits_st), sent=sent_h | sent_t))

    def _cat(parts, dtype):
        return np.concatenate(parts) if parts else np.zeros(0, dtype)

    timeline = ArrayTimeline(
        n, round_done, _cat(tx_t, float), _cat(tx_src, np.int64),
        _cat(tx_bits, float), _cat(tx_e, float), _cat(tx_att, np.int64),
        tx_dst=_cat(tx_dst, np.int64), tx_rnd=_cat(tx_rnd, np.int64),
        airtime_s=slot)
    fstar = _graph_fstar(q, xs, ys, d)
    losses = np.asarray([abs(o - fstar) for o in objs])
    return SimResult(topo=topo, timeline=timeline, states=states,
                     losses=losses, events=0, fstar=abs(fstar))
