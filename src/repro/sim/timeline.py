"""Timeline/energy accountant for the event-driven runtime.

Collects, as the simulation plays out:

  * every transmission (time, source, bits, Joules, airtime) — priced by
    sim.network through core.comm_model.tx_energy,
  * every per-worker round completion (wall-clock time of worker w
    finishing round k),
  * per-round state snapshots (optional; the bit-parity tests and the
    objective/loss traces are assembled from these).

and derives the paper-facing summaries: per-worker wall-clock and Joules,
cumulative-energy curves, and time/energy-to-target once the runner
attaches an objective trace.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class TxRecord:
    t: float
    src: int
    dst: int        # -1 = broadcast to all neighbors
    bits: float
    energy_j: float
    airtime_s: float
    attempt: int    # 0 = first transmission, >= 1 = retransmission


class Timeline:
    def __init__(self, n: int) -> None:
        self.n = n
        self.tx: list[TxRecord] = []
        # round_done[w] = list of completion times, index = round
        self.round_done: list[list[float]] = [[] for _ in range(n)]
        self.snapshots: dict[int, dict[int, Any]] = {}  # round -> worker -> snap
        self.dropped_at: dict[int, float] = {}

    # ----------------------------------------------------------- recording --
    def record_tx(self, t: float, src: int, dst: int, bits: float,
                  energy_j: float, airtime_s: float, attempt: int) -> None:
        self.tx.append(TxRecord(t, src, dst, bits, energy_j, airtime_s,
                                attempt))

    def record_round(self, worker: int, rnd: int, t: float) -> None:
        done = self.round_done[worker]
        assert rnd == len(done), (worker, rnd, len(done))
        done.append(t)

    def record_snapshot(self, worker: int, rnd: int, snap: Any) -> None:
        self.snapshots.setdefault(rnd, {})[worker] = snap

    def record_drop(self, worker: int, t: float) -> None:
        self.dropped_at[worker] = t

    # ------------------------------------------------------------- queries --
    def total_energy_j(self) -> float:
        return float(sum(r.energy_j for r in self.tx))

    def total_bits(self) -> float:
        return float(sum(r.bits for r in self.tx))

    def retransmissions(self) -> int:
        return sum(1 for r in self.tx if r.attempt > 0)

    def per_worker_energy_j(self) -> list[float]:
        out = [0.0] * self.n
        for r in self.tx:
            out[r.src] += r.energy_j
        return out

    def makespan_s(self) -> float:
        ends = [d[-1] for d in self.round_done if d]
        return max(ends) if ends else 0.0

    def rounds_completed(self) -> list[int]:
        return [len(d) for d in self.round_done]

    def global_round_times(self) -> list[float]:
        """t[k] = wall-clock at which EVERY non-dropped worker finished
        round k (the barrier view of an async run; in barriered mode this
        is just the slowest worker per round)."""
        alive = [w for w in range(self.n) if w not in self.dropped_at]
        counted = alive if alive else range(self.n)
        k_max = min((len(self.round_done[w]) for w in counted), default=0)
        return [max(self.round_done[w][k] for w in counted)
                for k in range(k_max)]

    def energy_until(self, t: float) -> float:
        """Joules spent up to wall-clock t (transmissions are billed at
        their start time)."""
        return float(sum(r.energy_j for r in self.tx if r.t <= t))

    def _cum_energy(self) -> tuple[list[float], list[float]]:
        times, cum, acc = [], [], 0.0
        for r in sorted(self.tx, key=lambda r: r.t):
            acc += r.energy_j
            times.append(r.t)
            cum.append(acc)
        return times, cum

    def to_target(self, losses: list[float], target: float
                  ) -> dict[str, float]:
        """First global round whose objective gap <= target, with its
        wall-clock time and the Joules spent until then.  Misses flow
        through as inf (the convention the benchmarks aggregate on)."""
        times = self.global_round_times()
        tx_t, tx_cum = self._cum_energy()
        for k, loss in enumerate(losses[: len(times)]):
            if loss <= target:
                t = times[k]
                j = bisect.bisect_right(tx_t, t)
                return {"round": float(k + 1), "time_s": t,
                        "energy_j": tx_cum[j - 1] if j else 0.0}
        return {"round": float("inf"), "time_s": float("inf"),
                "energy_j": float("inf")}

    def summary(self) -> dict:
        return {
            "total_energy_j": self.total_energy_j(),
            "total_bits": self.total_bits(),
            "retransmissions": self.retransmissions(),
            "makespan_s": self.makespan_s(),
            "rounds_completed": self.rounds_completed(),
            "per_worker_energy_j": self.per_worker_energy_j(),
            "dropped": dict(self.dropped_at),
        }


class ArrayTimeline:
    """Array-backed accountant for the vectorized engine (sim.vectorized).

    Same query API as :class:`Timeline`, but backed by flat numpy arrays
    instead of one Python TxRecord per message — the number of Python
    objects is O(1) in N and in the transmission count.  The vectorized
    engine has no link-layer drops (membership changes are participation
    schedules), so ``dropped_at`` is always empty; snapshots, when
    recorded, live on the runner side.
    """

    def __init__(self, n: int, round_done: np.ndarray, tx_t: np.ndarray,
                 tx_src: np.ndarray, tx_bits: np.ndarray,
                 tx_energy: np.ndarray, tx_attempt: np.ndarray) -> None:
        self.n = int(n)
        self.round_done_arr = np.asarray(round_done, float)  # (rounds, N)
        self.tx_t = np.asarray(tx_t, float)
        self.tx_src = np.asarray(tx_src, np.int64)
        self.tx_bits = np.asarray(tx_bits, float)
        self.tx_energy = np.asarray(tx_energy, float)
        self.tx_attempt = np.asarray(tx_attempt, np.int64)
        self.dropped_at: dict[int, float] = {}
        order = np.argsort(self.tx_t, kind="stable")
        self._t_sorted = self.tx_t[order]
        self._cum = np.cumsum(self.tx_energy[order])

    # ------------------------------------------------------------- queries --
    def total_energy_j(self) -> float:
        return float(self.tx_energy.sum())

    def total_bits(self) -> float:
        return float(self.tx_bits.sum())

    def retransmissions(self) -> int:
        return int((self.tx_attempt > 0).sum())

    def per_worker_energy_j(self) -> list[float]:
        return np.bincount(self.tx_src, weights=self.tx_energy,
                           minlength=self.n).tolist()

    def makespan_s(self) -> float:
        if not self.round_done_arr.size:
            return 0.0
        return float(self.round_done_arr[-1].max())

    def rounds_completed(self) -> list[int]:
        return [int(self.round_done_arr.shape[0])] * self.n

    def global_round_times(self) -> list[float]:
        if not self.round_done_arr.size:
            return []
        return self.round_done_arr.max(axis=1).tolist()

    def energy_until(self, t: float) -> float:
        j = int(np.searchsorted(self._t_sorted, t, side="right"))
        return float(self._cum[j - 1]) if j else 0.0

    def _cum_energy(self) -> tuple[list[float], list[float]]:
        return self._t_sorted.tolist(), self._cum.tolist()

    def to_target(self, losses: list[float], target: float
                  ) -> dict[str, float]:
        times = self.global_round_times()
        for k, loss in enumerate(losses[: len(times)]):
            if loss <= target:
                t = times[k]
                return {"round": float(k + 1), "time_s": t,
                        "energy_j": self.energy_until(t)}
        return {"round": float("inf"), "time_s": float("inf"),
                "energy_j": float("inf")}

    def summary(self) -> dict:
        return {
            "total_energy_j": self.total_energy_j(),
            "total_bits": self.total_bits(),
            "retransmissions": self.retransmissions(),
            "makespan_s": self.makespan_s(),
            "rounds_completed": self.rounds_completed(),
            "per_worker_energy_j": self.per_worker_energy_j(),
            "dropped": {},
        }
