"""Timeline/energy accountant for the event-driven runtime.

Collects, as the simulation plays out:

  * every transmission (time, source, bits, Joules, airtime, round) —
    priced by sim.network through core.comm_model.tx_energy,
  * every per-worker round completion (wall-clock time of worker w
    finishing round k),
  * per-round state snapshots (optional; the bit-parity tests and the
    objective/loss traces are assembled from these).

and derives the paper-facing summaries: per-worker wall-clock and Joules,
cumulative-energy curves, and time/energy-to-target once the runner
attaches an objective trace.

Two backings share one query implementation (``TimelineBase``):
``Timeline`` keeps a Python ``TxRecord`` per message (the events
engine), ``ArrayTimeline`` keeps flat numpy arrays (the vectorized
engine, O(1) Python objects in N).  Every query — and the obs.trace
Perfetto export — goes through ``tx_fields()``, the canonical
transmission-log accessor, so the two engines cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

_TX_FIELDS = ("t", "src", "dst", "bits", "energy_j", "airtime_s",
              "attempt", "rnd")


@dataclasses.dataclass
class TxRecord:
    t: float
    src: int
    dst: int        # -1 = broadcast to all neighbors
    bits: float
    energy_j: float
    airtime_s: float
    attempt: int    # 0 = first transmission, >= 1 = retransmission
    rnd: int = -1   # algorithm round the payload belongs to (-1 = unknown)


class TimelineBase:
    """Shared queries over the canonical transmission log.

    Subclasses provide ``n``, ``tx_fields()`` (time-ordered — both
    engines record with a monotone clock), ``dropped_at``, and the
    round-completion queries (their backings differ)."""

    n: int
    dropped_at: dict[int, float]

    def tx_fields(self) -> dict[str, np.ndarray]:
        """The transmission log as flat numpy arrays (keys ``_TX_FIELDS``),
        in recording order == time order."""
        raise NotImplementedError

    # ------------------------------------------------------------- rounds --
    def makespan_s(self) -> float:
        raise NotImplementedError

    def rounds_completed(self) -> list[int]:
        raise NotImplementedError

    def global_round_times(self) -> list[float]:
        """t[k] = wall-clock at which EVERY non-dropped worker finished
        round k (the barrier view of an async run; in barriered mode this
        is just the slowest worker per round)."""
        raise NotImplementedError

    # ------------------------------------------------------------- queries --
    def total_energy_j(self) -> float:
        return float(np.sum(self.tx_fields()["energy_j"]))

    def total_bits(self) -> float:
        return float(np.sum(self.tx_fields()["bits"]))

    def retransmissions(self) -> int:
        return int(np.sum(self.tx_fields()["attempt"] > 0))

    def per_worker_energy_j(self) -> list[float]:
        f = self.tx_fields()
        return np.bincount(f["src"], weights=f["energy_j"],
                           minlength=self.n).tolist()

    def energy_until(self, t: float) -> float:
        """Joules spent up to wall-clock t (transmissions are billed at
        their start time)."""
        t_sorted, cum = self._cum_energy_arr()
        j = int(np.searchsorted(t_sorted, t, side="right"))
        return float(cum[j - 1]) if j else 0.0

    def _cum_energy_arr(self) -> tuple[np.ndarray, np.ndarray]:
        f = self.tx_fields()
        order = np.argsort(f["t"], kind="stable")
        return f["t"][order], np.cumsum(f["energy_j"][order])

    def _cum_energy(self) -> tuple[list[float], list[float]]:
        t_sorted, cum = self._cum_energy_arr()
        return t_sorted.tolist(), cum.tolist()

    def to_target(self, losses: list[float], target: float
                  ) -> dict[str, float]:
        """First global round whose objective gap <= target, with its
        wall-clock time and the Joules spent until then.  Misses flow
        through as inf (the convention the benchmarks aggregate on)."""
        times = self.global_round_times()
        for k, loss in enumerate(losses[: len(times)]):
            if loss <= target:
                t = times[k]
                return {"round": float(k + 1), "time_s": t,
                        "energy_j": self.energy_until(t)}
        return {"round": float("inf"), "time_s": float("inf"),
                "energy_j": float("inf")}

    def summary(self) -> dict:
        return {
            "total_energy_j": self.total_energy_j(),
            "total_bits": self.total_bits(),
            "retransmissions": self.retransmissions(),
            "makespan_s": self.makespan_s(),
            "rounds_completed": self.rounds_completed(),
            "per_worker_energy_j": self.per_worker_energy_j(),
            "dropped": dict(self.dropped_at),
        }


class Timeline(TimelineBase):
    def __init__(self, n: int) -> None:
        self.n = n
        self.tx: list[TxRecord] = []
        # round_done[w] = list of completion times, index = round
        self.round_done: list[list[float]] = [[] for _ in range(n)]
        self.snapshots: dict[int, dict[int, Any]] = {}  # round -> worker -> snap
        self.dropped_at: dict[int, float] = {}
        self._fields_cache: tuple[int, dict[str, np.ndarray]] | None = None

    # ----------------------------------------------------------- recording --
    def record_tx(self, t: float, src: int, dst: int, bits: float,
                  energy_j: float, airtime_s: float, attempt: int,
                  rnd: int = -1) -> None:
        self.tx.append(TxRecord(t, src, dst, bits, energy_j, airtime_s,
                                attempt, rnd))

    def record_round(self, worker: int, rnd: int, t: float) -> None:
        done = self.round_done[worker]
        assert rnd == len(done), (worker, rnd, len(done))
        done.append(t)

    def record_snapshot(self, worker: int, rnd: int, snap: Any) -> None:
        self.snapshots.setdefault(rnd, {})[worker] = snap

    def record_drop(self, worker: int, t: float) -> None:
        self.dropped_at[worker] = t

    # ------------------------------------------------------------- queries --
    def tx_fields(self) -> dict[str, np.ndarray]:
        if self._fields_cache is not None \
                and self._fields_cache[0] == len(self.tx):
            return self._fields_cache[1]
        cols = list(zip(*((r.t, r.src, r.dst, r.bits, r.energy_j,
                           r.airtime_s, r.attempt, r.rnd)
                          for r in self.tx))) or [[]] * len(_TX_FIELDS)
        ints = {"src", "dst", "attempt", "rnd"}
        f = {k: np.asarray(c, np.int64 if k in ints else float)
             for k, c in zip(_TX_FIELDS, cols)}
        self._fields_cache = (len(self.tx), f)
        return f

    def makespan_s(self) -> float:
        ends = [d[-1] for d in self.round_done if d]
        return max(ends) if ends else 0.0

    def rounds_completed(self) -> list[int]:
        return [len(d) for d in self.round_done]

    def global_round_times(self) -> list[float]:
        alive = [w for w in range(self.n) if w not in self.dropped_at]
        counted = alive if alive else range(self.n)
        k_max = min((len(self.round_done[w]) for w in counted), default=0)
        return [max(self.round_done[w][k] for w in counted)
                for k in range(k_max)]


class ArrayTimeline(TimelineBase):
    """Array-backed accountant for the vectorized engine (sim.vectorized).

    Same query API as :class:`Timeline`, but backed by flat numpy arrays
    instead of one Python TxRecord per message — the number of Python
    objects is O(1) in N and in the transmission count.  The vectorized
    engine has no link-layer drops (membership changes are participation
    schedules), so ``dropped_at`` is always empty; snapshots, when
    recorded, live on the runner side.
    """

    def __init__(self, n: int, round_done: np.ndarray, tx_t: np.ndarray,
                 tx_src: np.ndarray, tx_bits: np.ndarray,
                 tx_energy: np.ndarray, tx_attempt: np.ndarray, *,
                 tx_dst: np.ndarray | None = None,
                 tx_rnd: np.ndarray | None = None,
                 airtime_s: float = 0.0) -> None:
        self.n = int(n)
        self.round_done_arr = np.asarray(round_done, float)  # (rounds, N)
        self.tx_t = np.asarray(tx_t, float)
        self.tx_src = np.asarray(tx_src, np.int64)
        self.tx_bits = np.asarray(tx_bits, float)
        self.tx_energy = np.asarray(tx_energy, float)
        self.tx_attempt = np.asarray(tx_attempt, np.int64)
        m = len(self.tx_t)
        self.tx_dst = (np.asarray(tx_dst, np.int64) if tx_dst is not None
                       else np.full(m, -1, np.int64))
        self.tx_rnd = (np.asarray(tx_rnd, np.int64) if tx_rnd is not None
                       else np.full(m, -1, np.int64))
        self.airtime_s = float(airtime_s)
        self.dropped_at: dict[int, float] = {}

    # ------------------------------------------------------------- queries --
    def tx_fields(self) -> dict[str, np.ndarray]:
        return {"t": self.tx_t, "src": self.tx_src, "dst": self.tx_dst,
                "bits": self.tx_bits, "energy_j": self.tx_energy,
                "airtime_s": np.full(len(self.tx_t), self.airtime_s),
                "attempt": self.tx_attempt, "rnd": self.tx_rnd}

    def makespan_s(self) -> float:
        if not self.round_done_arr.size:
            return 0.0
        return float(self.round_done_arr[-1].max())

    def rounds_completed(self) -> list[int]:
        return [int(self.round_done_arr.shape[0])] * self.n

    def global_round_times(self) -> list[float]:
        if not self.round_done_arr.size:
            return []
        return self.round_done_arr.max(axis=1).tolist()
