"""Channel + fault-injection models for the event-driven Q-GADMM runtime.

One worker broadcast = one radio transmission priced through
core.comm_model.tx_energy with the paper's Sec. V-A parameters
(RadioConfig): slot length tau, noise PSD, and a per-transmitter bandwidth
share equal to total_bandwidth / |transmitting color group| — exactly the
closed-form rule of comm_model.round_energy_topology, so an ideal-network
simulation reproduces the closed-form round energy to the Joule
(tests/test_sim.py asserts it).

On top of that closed-form core, the channel adds what the closed forms
cannot express:

  * per-link propagation latency + uniform delivery jitter,
  * i.i.d. per-attempt packet loss with bounded retransmit — every retry
    is a *unicast* to the neighbor that missed it, billed at that link's
    distance and occupying the sender for another slot,
  * ``transport='unicast'``: per-neighbor serialized transmissions
    instead of a single broadcast slot — this models the distributed
    trainer's C = max-degree sequential port exchanges (a star hub pays
    deg = N-1 slots per phase, the measured hub-serialization number in
    ROADMAP.md), while the default 'broadcast' models the paper's radio.
  * heterogeneous compute-time distributions, straggler multipliers, and
    scheduled worker drops (FaultPlan) with link-layer drop detection.

Determinism: every stochastic choice is drawn from a stream keyed by the
entity it belongs to — compute times per worker, loss/jitter per directed
link — so results do not depend on event interleaving.  Deliveries on a
directed link are FIFO (a retransmitted round-k payload can never be
overtaken by the round-k+1 payload), which the delta-coded quantizer
requires for sender==receiver sync.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.comm_model import RadioConfig, tx_energy


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Per-link channel model (shared by every link; distances differ)."""

    latency_s: float = 0.0        # propagation latency per delivery
    jitter_s: float = 0.0         # uniform [0, jitter_s) extra per delivery
    loss_prob: float = 0.0        # i.i.d. per-attempt packet loss
    max_retransmits: int = 100    # bounded: the link layer then declares
                                  # the payload through (keeps delta-coded
                                  # hats in sync and the event loop live)
    detection_delay_s: float = 0.0  # peer-down notification delay
    transport: str = "broadcast"  # 'broadcast' | 'unicast'

    def __post_init__(self):
        assert 0.0 <= self.loss_prob < 1.0, self.loss_prob
        assert self.transport in ("broadcast", "unicast"), self.transport
        assert self.max_retransmits >= 0


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Per-phase local computation time.

    base_s:        homogeneous mean compute time per phase.
    jitter_sigma:  lognormal sigma of a multiplicative per-(worker, phase)
                   draw; 0 = deterministic.
    straggler:     worker id -> multiplicative slowdown (e.g. {3: 10.0}).
    """

    base_s: float = 1e-3
    jitter_sigma: float = 0.0
    straggler: Mapping[int, float] = dataclasses.field(default_factory=dict)

    def factor(self, worker: int) -> float:
        return float(self.straggler.get(worker, 1.0))

    def sample(self, worker: int, rng: np.random.Generator) -> float:
        dt = self.base_s * self.factor(worker)
        if self.jitter_sigma > 0.0:
            dt *= float(rng.lognormal(0.0, self.jitter_sigma))
        return dt


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Scheduled membership changes.

    drop_round: worker id -> first round it does NOT start (it completes
                rounds 0..r-1, then goes permanently silent — a *leave*).
    join_round: worker id -> first round it participates (an *arrival*:
                the worker sits out rounds 0..r-1 exactly like a
                non-participating round — zero-initialized hat, neighbors
                advance over its absent rounds from the shared schedule,
                its edge duals stay frozen — then runs normally from
                round r).  Workers not listed join at round 0.
    """

    drop_round: Mapping[int, int] = dataclasses.field(default_factory=dict)
    join_round: Mapping[int, int] = dataclasses.field(default_factory=dict)

    def drops_at(self, worker: int) -> int | None:
        r = self.drop_round.get(worker)
        return None if r is None else int(r)

    def joins_at(self, worker: int) -> int:
        return int(self.join_round.get(worker, 0))


class Network:
    """The modeled medium between actors.

    Actors are registered with `register`; `broadcast` puts one phase
    payload on the air and schedules `on_message(msg)` on every live
    neighbor, returning the time the sender's radio frees up.
    """

    def __init__(self, engine, topo, placement, radio: RadioConfig,
                 ncfg: NetworkConfig, timeline, seed: int = 0) -> None:
        self.engine = engine
        self.topo = topo
        self.radio = radio
        self.ncfg = ncfg
        self.timeline = timeline
        self._actors: list[Any] = []
        # per-edge link distances only — retransmit/unicast pricing never
        # needs a pair that is not a topology edge, so the O(N^2) full
        # pairwise matrix the pre-scale implementation kept is gone
        self._link_dist: dict[tuple[int, int], float] = {}
        if topo.num_edges:
            for (u, v), d in zip(topo.edges.tolist(),
                                 placement.edge_dists().tolist()):
                self._link_dist[(u, v)] = self._link_dist[(v, u)] = float(d)
        self._bcast_dist = placement.broadcast_dist()
        heads = int(topo.head_mask.sum())
        tails = topo.n - heads
        self._group_size = np.where(topo.head_mask, max(heads, 1),
                                    max(tails, 1))
        self._link_rng: dict[tuple[int, int], np.random.Generator] = {
            (int(u), int(v)): np.random.default_rng([seed, 7, int(u), int(v)])
            for u, v in np.vstack([topo.edges, topo.edges[:, ::-1]])
        } if topo.num_edges else {}
        self._fifo_floor: dict[tuple[int, int], float] = {}

    def register(self, actors) -> None:
        self._actors = list(actors)

    def bw_share(self, src: int) -> float:
        """Bandwidth of one transmitter: the total band is shared within
        the phase's transmitting color group (the head/tail alternation is
        exactly the paper's 2*Btot/N rule on a balanced chain)."""
        return self.radio.total_bandwidth_hz / float(self._group_size[src])

    # ------------------------------------------------------------ sending --
    def _tx(self, t: float, src: int, dst: int, bits: float, dist_m: float,
            attempt: int, rnd: int = -1) -> float:
        e = tx_energy(bits, dist_m, self.bw_share(src), self.radio.slot_s,
                      self.radio.noise_psd)
        self.timeline.record_tx(t, src, dst, bits, e, self.radio.slot_s,
                                attempt, rnd=rnd)
        return e

    def _deliver(self, src: int, dst: int, t_ready: float, msg) -> None:
        """Schedule delivery with latency + jitter, FIFO per directed
        link."""
        rng = self._link_rng[(src, dst)]
        jitter = (float(rng.uniform(0.0, self.ncfg.jitter_s))
                  if self.ncfg.jitter_s > 0.0 else 0.0)
        t = t_ready + self.ncfg.latency_s + jitter
        key = (src, dst)
        t = max(t, self._fifo_floor.get(key, 0.0))
        self._fifo_floor[key] = t
        actor = self._actors[dst]
        self.engine.at(t, lambda: actor.on_message(msg))

    def _attempts(self, src: int, dst: int) -> int:
        """1 + number of retransmissions this delivery needs (bounded)."""
        if self.ncfg.loss_prob <= 0.0:
            return 1
        rng = self._link_rng[(src, dst)]
        a = 1
        while (a <= self.ncfg.max_retransmits
               and float(rng.uniform()) < self.ncfg.loss_prob):
            a += 1
        return a

    def broadcast(self, src: int, bits: float, msg) -> float:
        """Put one phase payload on the air; returns the sender's
        radio-free time.

        transport='broadcast': one slot covers all neighbors (energy at
        the farthest-neighbor distance, the paper's power rule); each
        neighbor whose copy is lost gets serialized unicast retransmits.
        transport='unicast': deg(src) serialized per-link transmissions
        (the trainer's sequential port exchanges), each with its own
        loss/retransmit draws.
        """
        t0 = self.engine.now
        slot = self.radio.slot_s
        rnd = int(getattr(msg, "rnd", -1))
        nbrs = [int(j) for j in self.topo.neighbors(src)]
        if not nbrs:
            return t0
        t_busy = t0
        if self.ncfg.transport == "broadcast":
            self._tx(t0, src, -1, bits, float(self._bcast_dist[src]), 0,
                     rnd=rnd)
            t_busy = t0 + slot
            late: list[tuple[int, int]] = []
            for j in nbrs:
                a = self._attempts(src, j)
                if a == 1:
                    self._deliver(src, j, t_busy, msg)
                else:
                    late.append((j, a))
            # serialized unicast retransmissions, neighbor-id order
            for j, a in late:
                for k in range(a - 1):
                    self._tx(t_busy, src, j, bits,
                             self._link_dist[(src, j)], k + 1, rnd=rnd)
                    t_busy += slot
                self._deliver(src, j, t_busy, msg)
        else:
            for j in nbrs:
                a = self._attempts(src, j)
                for k in range(a):
                    self._tx(t_busy, src, j, bits,
                             self._link_dist[(src, j)], k, rnd=rnd)
                    t_busy += slot
                self._deliver(src, j, t_busy, msg)
        return t_busy

    # -------------------------------------------------------------- drops --
    def announce_drop(self, src: int) -> None:
        """Link-layer failure detection: neighbors learn (after
        detection_delay_s) that `src` is gone and stop waiting on it."""
        self.timeline.record_drop(src, self.engine.now)
        for j in self.topo.neighbors(src):
            actor = self._actors[int(j)]
            self.engine.after(self.ncfg.detection_delay_s,
                              lambda a=actor, s=src: a.on_peer_down(s))
