"""Deterministic discrete-event engine (clock + priority queue).

The whole simulator is driven by one ``Engine``: actors and channels
schedule callbacks at absolute times, ``run()`` pops them in (time,
sequence) order.  Two properties matter:

  * **Determinism** — ties on the timestamp are broken by insertion order
    (a monotone sequence number), never by hash order or heap internals.
    An ideal network collapses every round onto identical timestamps, and
    the bit-parity contract (tests/test_sim.py) needs the replay to be
    exactly repeatable.
  * **Liveness** — ``run()`` counts processed events against a hard budget
    and raises :class:`SimLivenessError` instead of spinning forever.  A
    protocol bug that schedules unboundedly (or a retransmit loop that
    never gives up) is surfaced as a failure, not a hang; the hypothesis
    property suite drives random topology x censoring x loss x drops
    through this guard.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable


class SimLivenessError(RuntimeError):
    """The event loop exceeded its event budget — a scheduling bug or an
    unbounded retransmit/requeue loop, never a legitimate long run (size
    the budget from rounds * workers * degree; see Engine.run)."""


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)


class Engine:
    """Event loop with a monotone clock.

    now:    current simulation time (seconds); only advances inside run().
    at/after: schedule a zero-arg callback at an absolute/relative time.
    """

    def __init__(self) -> None:
        self.now: float = 0.0
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.events_processed = 0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        assert time >= self.now - 1e-12, (
            f"scheduling into the past: {time} < {self.now}")
        heapq.heappush(self._heap, _Event(float(time), next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        assert delay >= 0.0, f"negative delay {delay}"
        self.at(self.now + delay, fn)

    def __len__(self) -> int:
        return len(self._heap)

    def run(self, max_events: int = 1_000_000,
            until: float | None = None) -> int:
        """Process events until the queue drains (or `until` is passed).

        Returns the number of events processed in this call.  Raises
        SimLivenessError once more than `max_events` events have been
        processed over the engine's lifetime — the deadlock/livelock guard
        the property tests lean on.
        """
        start = self.events_processed
        while self._heap:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            self.now = max(self.now, ev.time)
            self.events_processed += 1
            if self.events_processed > max_events:
                raise SimLivenessError(
                    f"event budget exceeded ({max_events}): the scheduler "
                    "is not quiescing — protocol deadlock would show as a "
                    "drained queue with unfinished workers, a livelock "
                    "shows up here")
            ev.fn()
        return self.events_processed - start
