"""Sharding-aware npz checkpointing (no external deps).

Saves a pytree of (possibly sharded) arrays to <dir>/step_<n>.npz plus a
sidecar JSON with the treedef and metadata.  Restore rebuilds the pytree and
(optionally) re-places leaves with provided shardings.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, metadata: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    np.savez(path, **arrays)
    meta = {"names": names, "step": step, **(metadata or {})}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """`like` provides the pytree structure (and dtypes for casting).

    Raises ValueError naming the offending leaf when the checkpoint does not
    match `like` (leaf count, per-leaf shape, or sidecar tree paths), instead
    of silently mis-assigning arrays to leaves or failing deep inside a cast.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        arrays = [data[f"a{i}"] for i in range(len(data.files))]
    names, flat, treedef = _flatten_with_names(like)
    if len(flat) != len(arrays):
        raise ValueError(
            f"checkpoint {path} has {len(arrays)} leaves, expected "
            f"{len(flat)}: the saved tree structure does not match `like`")
    meta_path = path + ".json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            saved_names = json.load(f).get("names")
        if saved_names is not None and list(saved_names) != names:
            diff = next((i, s, n) for i, (s, n)
                        in enumerate(zip(saved_names, names)) if s != n)
            raise ValueError(
                f"checkpoint {path} tree paths do not match `like`: "
                f"leaf {diff[0]} saved as {diff[1]!r}, expected {diff[2]!r}")
    for name, a, l in zip(names, arrays, flat):
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(
                f"checkpoint {path} leaf {name!r} has shape {tuple(a.shape)},"
                f" expected {tuple(np.shape(l))}")
    leaves = [np.asarray(a, dtype=l.dtype) for a, l in zip(arrays, flat)]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
