"""Dense GQA decoder (nemotron-4, qwen1.5, gemma3, and the LLaVA backbone).

Layers are stacked (leading L dim) and run under jax.lax.scan so the HLO stays
compact for 40-100 layer models; per-layer sliding windows (gemma3's 5 local :
1 global pattern) ride along the scan as data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig

Array = jax.Array


def _stack(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init(key: Array, cfg: ArchConfig) -> dict:
    k_emb, k_attn, k_mlp = jax.random.split(key, 3)
    params = {
        "embed": L.init_embed(k_emb, cfg),
        "blocks": {
            "attn": _stack(k_attn, cfg.n_layers, lambda k: L.init_attn(k, cfg)),
            "mlp": _stack(k_mlp, cfg.n_layers,
                          lambda k: L.init_mlp(k, cfg.d_model, cfg.d_ff,
                                               cfg.activation, cfg.param_dtype)),
            "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), cfg.param_dtype),
        },
    }
    return params


def layer_windows(cfg: ArchConfig) -> Array:
    return jnp.asarray([cfg.window_for_layer(i) for i in range(cfg.n_layers)],
                       jnp.int32)


def _block(x, blk, window, cfg: ArchConfig, positions):
    h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
    x = x + L.attention(blk["attn"], h, cfg, positions, window=window)
    h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
    x = x + L.mlp(blk["mlp"], h, cfg.activation)
    # re-seed the residual-stream sharding each block (sequence parallelism
    # relies on GSPMD inserting the gather/scatter pair around attention/MLP)
    return L.constrain_act(x)


def forward(params: dict, tokens: Array, cfg: ArchConfig,
            extra_embeds: Array | None = None) -> Array:
    """Returns final hidden states (B, S(+P), d)."""
    x = L.embed(params["embed"], tokens, cfg)
    if extra_embeds is not None:  # VLM early fusion: prepend patch embeddings
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    windows = layer_windows(cfg)

    block = _block
    if cfg.remat:
        block = jax.checkpoint(_block, static_argnums=(3,))

    if cfg.scan_layers:
        def body(x, inp):
            blk, window = inp
            return block(x, blk, window, cfg, positions), None
        x, _ = jax.lax.scan(body, x, (params["blocks"], windows))
    else:
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda a: a[i], params["blocks"])
            x = block(x, blk, windows[i], cfg, positions)
    return x


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    x = forward(params, batch["tokens"], cfg, batch.get("patches"))
    if "patches" in batch and batch["patches"] is not None:
        x = x[:, batch["patches"].shape[1]:]  # loss only on text positions
    logits = L.unembed(params["embed"], x, cfg)
    return L.softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)


# ------------------------------------------------------------ serving -------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.padded_kv_heads(), cfg.dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params: dict, tokens: Array, cfg: ArchConfig,
            extra_embeds: Array | None = None):
    """Full-sequence forward that also materializes the KV cache.

    Returns (logits_last (B, vocab), cache).
    """
    x = L.embed(params["embed"], tokens, cfg)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    windows = layer_windows(cfg)

    def body(x, inp):
        blk, window = inp
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        q, k, v = L._qkv(blk["attn"], h, cfg, positions)
        out = L._sdpa_blocked(q, k, v, positions, positions, window,
                              cfg.attn_q_block)
        x = x + L.proj_out(blk["attn"], out, cfg)
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, cfg.activation)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"k": ks, "v": vs}


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    """One decode step. token (B,), pos (B,) current position; returns
    (logits (B, vocab), new_cache)."""
    if "k_loc" in cache:
        return decode_step_windowed(params, token, cache, pos, cfg)
    x = L.embed(params["embed"], token[:, None], cfg)
    windows = layer_windows(cfg)

    def body(x, inp):
        blk, window, ck, cv = inp
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        out, ck, cv = L.attention_decode(blk["attn"], h, cfg, ck, cv, pos,
                                         window=window)
        x = x + out
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, cfg.activation)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows,
                                         cache["k"], cache["v"]))
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs}


# ------------------------- windowed cache (gemma3-style 5 local : 1 global) --
# Local (sliding-window) layers keep only `window` KV slots in a ring buffer;
# global layers keep the full horizon.  For gemma3-27b at 500k this shrinks
# the KV cache ~5.9x: (52*1024 + 10*S) vs 62*S slots.  See EXPERIMENTS §Perf.
def _period_counts(cfg: ArchConfig) -> tuple[int, int]:
    ge = cfg.global_every
    n_per = cfg.n_layers // ge          # complete (ge-1 local + 1 global) periods
    rem = cfg.n_layers - n_per * ge     # trailing local layers
    return n_per, rem


def _regroup_blocks(params: dict, cfg: ArchConfig):
    """(L, ...) stacked blocks -> (periods of ge-1 locals, globals, remainder)."""
    ge = cfg.global_every
    n_per, rem = _period_counts(cfg)
    take = lambda tree, idx: jax.tree.map(lambda a: a[jnp.asarray(idx)], tree)
    loc_idx = [[p * ge + j for j in range(ge - 1)] for p in range(n_per)]
    glob_idx = [p * ge + ge - 1 for p in range(n_per)]
    rem_idx = list(range(n_per * ge, cfg.n_layers))
    blocks = params["blocks"]
    locs = take(blocks, loc_idx)        # (n_per, ge-1, ...)
    globs = take(blocks, glob_idx)      # (n_per, ...)
    rems = take(blocks, rem_idx) if rem else None
    return locs, globs, rems


def init_cache_windowed(cfg: ArchConfig, batch: int, max_seq: int,
                        dtype=None) -> dict:
    assert cfg.global_every and cfg.sliding_window
    dtype = dtype or cfg.compute_dtype
    n_per, rem = _period_counts(cfg)
    win = min(cfg.sliding_window, max_seq)
    kvh, dh = cfg.padded_kv_heads(), cfg.dh
    ge = cfg.global_every
    return {
        "k_loc": jnp.zeros((n_per, ge - 1, batch, win, kvh, dh), dtype),
        "v_loc": jnp.zeros((n_per, ge - 1, batch, win, kvh, dh), dtype),
        "k_glob": jnp.zeros((n_per, batch, max_seq, kvh, dh), dtype),
        "v_glob": jnp.zeros((n_per, batch, max_seq, kvh, dh), dtype),
        "k_rem": jnp.zeros((rem, batch, win, kvh, dh), dtype),
        "v_rem": jnp.zeros((rem, batch, win, kvh, dh), dtype),
    }


def decode_step_windowed(params: dict, token: Array, cache: dict, pos: Array,
                         cfg: ArchConfig):
    x = L.embed(params["embed"], token[:, None], cfg)
    locs, globs, rems = _regroup_blocks(params, cfg)

    def local_layer(x, inp):
        blk, ck, cv = inp
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        out, ck, cv = L.attention_decode_ring(blk["attn"], h, cfg, ck, cv, pos)
        x = x + out
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, cfg.activation)
        return x, (ck, cv)

    def period(x, inp):
        loc_blk, lk, lv, glob_blk, gk, gv = inp
        x, (lk, lv) = jax.lax.scan(local_layer, x, (loc_blk, lk, lv))
        h = L.rmsnorm(x, glob_blk["ln1"], cfg.rms_eps)
        out, gk, gv = L.attention_decode(glob_blk["attn"], h, cfg, gk, gv, pos)
        x = x + out
        h = L.rmsnorm(x, glob_blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(glob_blk["mlp"], h, cfg.activation)
        return x, (lk, lv, gk, gv)

    x, (lks, lvs, gks, gvs) = jax.lax.scan(
        period, x, (locs, cache["k_loc"], cache["v_loc"], globs,
                    cache["k_glob"], cache["v_glob"]))
    if rems is not None and cache["k_rem"].shape[0]:
        x, (rks, rvs) = jax.lax.scan(local_layer, x,
                                     (rems, cache["k_rem"], cache["v_rem"]))
    else:
        rks, rvs = cache["k_rem"], cache["v_rem"]
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k_loc": lks, "v_loc": lvs, "k_glob": gks, "v_glob": gvs,
                    "k_rem": rks, "v_rem": rvs}
