"""MoE decoder LMs: qwen3-moe (every layer MoE, top-8) and
llama4-maverick (alternating dense/MoE, top-1 + shared expert)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from .moe import init_moe, moe_apply

Array = jax.Array


def _stack(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init(key: Array, cfg: ArchConfig) -> dict:
    m = cfg.moe
    keys = jax.random.split(key, 8)
    params = {"embed": L.init_embed(keys[0], cfg)}
    if m.moe_every == 1:
        params["blocks"] = {
            "attn": _stack(keys[1], cfg.n_layers, lambda k: L.init_attn(k, cfg)),
            "moe": _stack(keys[2], cfg.n_layers, lambda k: init_moe(k, cfg)),
            "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), cfg.param_dtype),
        }
    else:
        assert m.moe_every == 2 and cfg.n_layers % 2 == 0
        pairs = cfg.n_layers // 2
        params["blocks"] = {
            "attn_d": _stack(keys[1], pairs, lambda k: L.init_attn(k, cfg)),
            "mlp": _stack(keys[2], pairs,
                          lambda k: L.init_mlp(k, cfg.d_model, cfg.d_ff,
                                               cfg.activation, cfg.param_dtype)),
            "attn_m": _stack(keys[3], pairs, lambda k: L.init_attn(k, cfg)),
            "moe": _stack(keys[4], pairs, lambda k: init_moe(k, cfg)),
            "ln": jnp.zeros((pairs, 4, cfg.d_model), cfg.param_dtype),
        }
    return params


def _moe_block(x, attn_p, moe_p, ln1, ln2, cfg, positions):
    h = L.rmsnorm(x, ln1, cfg.rms_eps)
    x = x + L.attention(attn_p, h, cfg, positions, window=0)
    h = L.rmsnorm(x, ln2, cfg.rms_eps)
    out, aux = moe_apply(moe_p, h, cfg)
    return x + out, aux


def _dense_block(x, attn_p, mlp_p, ln1, ln2, cfg, positions):
    h = L.rmsnorm(x, ln1, cfg.rms_eps)
    x = x + L.attention(attn_p, h, cfg, positions, window=0)
    h = L.rmsnorm(x, ln2, cfg.rms_eps)
    return x + L.mlp(mlp_p, h, cfg.activation)


def forward(params: dict, tokens: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    blocks = params["blocks"]
    m = cfg.moe

    if m.moe_every == 1:
        def body(carry, blk):
            x, aux = carry
            def f(x):
                return _moe_block(x, blk["attn"], blk["moe"], blk["ln1"],
                                  blk["ln2"], cfg, positions)
            if cfg.remat:
                f = jax.checkpoint(f)
            x, a = f(x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    else:
        def body(carry, blk):
            x, aux = carry
            def f(x):
                x = _dense_block(x, blk["attn_d"], blk["mlp"], blk["ln"][0],
                                 blk["ln"][1], cfg, positions)
                return _moe_block(x, blk["attn_m"], blk["moe"], blk["ln"][2],
                                  blk["ln"][3], cfg, positions)
            if cfg.remat:
                f = jax.checkpoint(f)
            x, a = f(x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    x, aux = forward(params, batch["tokens"], cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return L.softmax_xent(logits, batch["labels"], mode=cfg.xent_mode) + aux


# ------------------------------------------------------------- serving ------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.padded_kv_heads(), cfg.dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _split_cache(cache, cfg):
    """(L, ...) caches -> per-scan-step layout."""
    m = cfg.moe
    if m.moe_every == 1:
        return cache["k"], cache["v"]
    pairs = cfg.n_layers // 2
    k = cache["k"].reshape(pairs, 2, *cache["k"].shape[1:])
    v = cache["v"].reshape(pairs, 2, *cache["v"].shape[1:])
    return k, v


def prefill(params: dict, tokens: Array, cfg: ArchConfig):
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    blocks = params["blocks"]
    m = cfg.moe

    def attn_cache(attn_p, x, ln, cfg):
        h = L.rmsnorm(x, ln, cfg.rms_eps)
        q, k, v = L._qkv(attn_p, h, cfg, positions)
        out = L._sdpa_blocked(q, k, v, positions, positions, 0, cfg.attn_q_block)
        return x + jnp.einsum("bshk,hkd->bsd", out,
                              attn_p["wo"].astype(x.dtype)), k, v

    if m.moe_every == 1:
        def body(x, blk):
            x, k, v = attn_cache(blk["attn"], x, blk["ln1"], cfg)
            h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
            out, _ = moe_apply(blk["moe"], h, cfg)
            return x + out, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, blocks)
    else:
        def body(x, blk):
            x, k1, v1 = attn_cache(blk["attn_d"], x, blk["ln"][0], cfg)
            h = L.rmsnorm(x, blk["ln"][1], cfg.rms_eps)
            x = x + L.mlp(blk["mlp"], h, cfg.activation)
            x, k2, v2 = attn_cache(blk["attn_m"], x, blk["ln"][2], cfg)
            h = L.rmsnorm(x, blk["ln"][3], cfg.rms_eps)
            out, _ = moe_apply(blk["moe"], h, cfg)
            return x + out, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        x, (ks, vs) = jax.lax.scan(body, x, blocks)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"k": ks.reshape(cfg.n_layers, *ks.shape[-4:]),
                    "v": vs.reshape(cfg.n_layers, *vs.shape[-4:])}


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    x = L.embed(params["embed"], token[:, None], cfg)
    blocks = params["blocks"]
    m = cfg.moe
    ck, cv = _split_cache(cache, cfg)

    if m.moe_every == 1:
        def body(x, inp):
            blk, k, v = inp
            h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
            out, k, v = L.attention_decode(blk["attn"], h, cfg, k, v, pos)
            x = x + out
            h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
            mo, _ = moe_apply(blk["moe"], h, cfg)
            return x + mo, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, (blocks, ck, cv))
    else:
        def body(x, inp):
            blk, k, v = inp
            h = L.rmsnorm(x, blk["ln"][0], cfg.rms_eps)
            out, k1, v1 = L.attention_decode(blk["attn_d"], h, cfg, k[0], v[0], pos)
            x = x + out
            h = L.rmsnorm(x, blk["ln"][1], cfg.rms_eps)
            x = x + L.mlp(blk["mlp"], h, cfg.activation)
            h = L.rmsnorm(x, blk["ln"][2], cfg.rms_eps)
            out, k2, v2 = L.attention_decode(blk["attn_m"], h, cfg, k[1], v[1], pos)
            x = x + out
            h = L.rmsnorm(x, blk["ln"][3], cfg.rms_eps)
            mo, _ = moe_apply(blk["moe"], h, cfg)
            return x + mo, (jnp.stack([k1, k2]), jnp.stack([v1, v2]))
        x, (ks, vs) = jax.lax.scan(body, x, (blocks, ck, cv))
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k": ks.reshape(cfg.n_layers, *ks.shape[-4:]),
                    "v": vs.reshape(cfg.n_layers, *vs.shape[-4:])}
