"""Architecture registry: --arch <id> -> (config, model module)."""
from __future__ import annotations

import importlib
from typing import Any

from .config import ArchConfig

ARCHS = [
    "nemotron-4-340b",
    "qwen1.5-32b",
    "qwen3-moe-235b-a22b",
    "llava-next-mistral-7b",
    "llama4-maverick-400b-a17b",
    "gemma3-27b",
    "zamba2-2.7b",
    "mamba2-2.7b",
    "whisper-tiny",
    "qwen1.5-4b",
]

_FAMILY_MODULE = {
    "dense": "repro.models.dense",
    "moe": "repro.models.moe_model",
    "ssm": "repro.models.ssm",
    "hybrid": "repro.models.zamba",
    "audio": "repro.models.encdec",
    "vlm": "repro.models.vlm",
}


def _config_module(arch: str):
    mod = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str, smoke: bool = False, **kw) -> ArchConfig:
    m = _config_module(arch)
    return m.smoke_config(**kw) if smoke else m.config(**kw)


def get_model(cfg: ArchConfig) -> Any:
    """Returns the model module: init, loss_fn, init_cache, prefill, decode_step."""
    return importlib.import_module(_FAMILY_MODULE[cfg.family])


def make_batch_specs(cfg: ArchConfig, batch: int, seq: int, kind: str = "train"):
    """ShapeDtypeStructs for this arch's inputs (see launch.dryrun)."""
    import jax
    import jax.numpy as jnp

    sds = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        b = {"tokens": sds((batch, seq), jnp.int32)}
        if kind == "train":
            b["labels"] = sds((batch, seq), jnp.int32)
        if cfg.family == "vlm":
            b["patches"] = sds((batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            b["frames"] = sds((batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
        return b
    raise ValueError(kind)
