"""Shared layer primitives: RMSNorm, RoPE, GQA attention (full / windowed /
flash-style query-blocked / decode-with-cache), MLP variants, embeddings.

All functions are pure jnp and GSPMD-friendly (no explicit collectives;
sharding comes from pjit annotations on the inputs/params).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ArchConfig

Array = jax.Array
NEG_INF = -1e30

# Activation-sharding context: the trainer/server install a NamedSharding for
# the residual stream (batch dims sharded, d replicated).  One constraint at
# the embedding output seeds GSPMD propagation — without it a d-sharded embed
# table leaks a d-sharded residual stream into every layer (per-layer
# all-reduces of full activations; see EXPERIMENTS.md §Perf pair 1).
_ACT_SHARDING = None


def set_activation_sharding(sharding) -> None:
    global _ACT_SHARDING
    _ACT_SHARDING = sharding


def constrain_act(x: Array) -> Array:
    if _ACT_SHARDING is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SHARDING)


# ----------------------------------------------------------------- norms ----
def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ------------------------------------------------------------------ rope ----
def rope_freqs(dh: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, Dh), positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # (B, S, 1, Dh/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention ----
@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    dh: int


def init_attn(key: Array, cfg: ArchConfig) -> dict:
    d, dh = cfg.d_model, cfg.dh
    h, kvh = cfg.padded_heads(), cfg.padded_kv_heads()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, h, dh)) * s).astype(cfg.param_dtype),
        "wk": (jax.random.normal(k2, (d, kvh, dh)) * s).astype(cfg.param_dtype),
        "wv": (jax.random.normal(k3, (d, kvh, dh)) * s).astype(cfg.param_dtype),
        "wo": (jax.random.normal(k4, (h, dh, d)) * s).astype(cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), cfg.param_dtype)
        p["bk"] = jnp.zeros((kvh, dh), cfg.param_dtype)
        p["bv"] = jnp.zeros((kvh, dh), cfg.param_dtype)
    return p


def head_mask(cfg: ArchConfig, h: int) -> Array | None:
    """(H',) 0/1 mask killing padded heads' outputs (exactness under head
    padding: masked heads contribute nothing and receive no gradients)."""
    if h == cfg.n_heads:
        return None
    return (jnp.arange(h) < cfg.n_heads).astype(jnp.float32)


def proj_out(p: dict, out: Array, cfg: ArchConfig) -> Array:
    """Output projection with padded-head masking. out: (B, S, H', Dh)."""
    m = head_mask(cfg, out.shape[-2])
    if m is not None:
        out = out * m[None, None, :, None].astype(out.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


def _qkv(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_blocked(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                  window, q_block: int, scan_remat: bool = False) -> Array:
    """Flash-style attention: scan over query blocks with full K/V per block.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KVH, Dh).  Causal via positions; optional
    sliding window (0/None = full).  Peak temp is (B, H, q_block, Sk) instead
    of (B, H, Sq, Sk).
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qb = min(q_block, sq)
    n_blocks = -(-sq // qb)
    pad = n_blocks * qb - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qs = q.reshape(b, n_blocks, qb, h, dh).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(b, n_blocks, qb).transpose(1, 0, 2)

    kk = k.reshape(b, -1, kvh, 1, dh)
    vv = v.reshape(b, -1, kvh, 1, dh)

    # window is 0 (full) or a size; may be a traced per-layer scalar under scan
    eff_window = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window),
                           jnp.iinfo(jnp.int32).max)

    def body(carry, inp):
        qi, qpi = inp  # (B, qb, H, Dh), (B, qb)
        qi = qi.reshape(b, qb, kvh, groups, dh)
        logits = jnp.einsum("bqkgd,bskxd->bkgqs", qi.astype(jnp.float32),
                            kk.astype(jnp.float32)) * scale
        delta = qpi[:, None, None, :, None] - k_pos[:, None, None, None, :]
        mask = (delta >= 0) & (delta < eff_window)
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskxd->bqkgd", w.astype(vv.dtype), vv)
        return carry, out.reshape(b, qb, h, dh)

    if n_blocks == 1:
        _, out = body(None, (qs[0], qps[0]))
        outs = out[None]
    else:
        # scan_remat: recompute each block's (qb x Sk) scores in the backward
        # pass instead of saving them as AD residuals — drops the dominant
        # f32 scores buffer from activation memory (flash-attention-style).
        b_fn = jax.checkpoint(body) if scan_remat else body
        _, outs = jax.lax.scan(b_fn, None, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * qb, h, dh)
    return out[:, :sq]


def attention(p: dict, x: Array, cfg: ArchConfig, positions: Array,
              window: int | Array = 0) -> Array:
    """Training / prefill self-attention (causal, optional sliding window)."""
    q, k, v = _qkv(p, x, cfg, positions)
    out = _sdpa_blocked(q, k, v, positions, positions, window,
                        cfg.attn_q_block, scan_remat=cfg.attn_scan_remat)
    return proj_out(p, out, cfg)


def attention_decode(p: dict, x: Array, cfg: ArchConfig, cache_k: Array,
                     cache_v: Array, pos: Array, window: int | Array = 0):
    """One-token decode: x (B, 1, d); cache_{k,v} (B, S, KVH, Dh); pos (B,).

    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    b, s, kvh, dh = cache_k.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # scatter new kv at pos (dynamic per batch): one-hot to stay pjit-friendly
    onehot = (jnp.arange(s)[None, :] == pos[:, None]).astype(cache_k.dtype)
    cache_k = cache_k * (1 - onehot[..., None, None]) + onehot[..., None, None] * k
    cache_v = cache_v * (1 - onehot[..., None, None]) + onehot[..., None, None] * v
    h = q.shape[2]  # may exceed cfg.n_heads under head padding
    groups = h // kvh
    qg = q.reshape(b, 1, kvh, groups, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) / jnp.sqrt(dh)
    kpos = jnp.arange(s)[None, :]
    eff_window = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window),
                           jnp.iinfo(jnp.int32).max)
    delta = pos[:, None] - kpos
    mask = (delta >= 0) & (delta < eff_window)
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(cache_v.dtype), cache_v)
    out = out.reshape(b, 1, h, dh)
    return proj_out(p, out, cfg), cache_k, cache_v


def attention_decode_ring(p: dict, x: Array, cfg: ArchConfig, cache_k: Array,
                          cache_v: Array, pos: Array):
    """One-token decode against a RING buffer of the last `win` positions
    (sliding-window layers: cache is win slots, slot = pos % win).

    Exact match with attention_decode+window masking as long as win >= the
    layer's sliding window.  Keys carry absolute-position RoPE; attention is
    permutation-invariant over slots so ring order needs no unrotation.
    """
    b, win, kvh, dh = cache_k.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % win
    onehot = (jnp.arange(win)[None, :] == slot[:, None]).astype(cache_k.dtype)
    cache_k = cache_k * (1 - onehot[..., None, None]) + onehot[..., None, None] * k
    cache_v = cache_v * (1 - onehot[..., None, None]) + onehot[..., None, None] * v
    h = q.shape[2]  # may exceed cfg.n_heads under head padding
    groups = h // kvh
    qg = q.reshape(b, 1, kvh, groups, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) / jnp.sqrt(dh)
    valid = jnp.arange(win)[None, :] < jnp.minimum(pos[:, None] + 1, win)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(cache_v.dtype), cache_v)
    out = out.reshape(b, 1, h, dh)
    return proj_out(p, out, cfg), cache_k, cache_v


def cross_attention(p: dict, x: Array, enc: Array, cfg: ArchConfig) -> Array:
    """Enc-dec cross attention (no rope, no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(x.dtype))
    kvh, h = k.shape[2], q.shape[2]
    groups = h // kvh
    b, sq, _, dh = q.shape
    qg = q.reshape(b, sq, kvh, groups, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v).reshape(b, sq, h, dh)
    return proj_out(p, out, cfg)


# ------------------------------------------------------------------- mlp ----
def init_mlp(key: Array, d: int, ff: int, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(ff)
    p = {"w_up": (jax.random.normal(ks[0], (d, ff)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(ks[1], (ff, d)) * s_out).astype(dtype)}
    if activation == "silu":
        p["w_gate"] = (jax.random.normal(ks[2], (d, ff)) * s_in).astype(dtype)
    return p


def mlp(p: dict, x: Array, activation: str) -> Array:
    up = x @ p["w_up"].astype(x.dtype)
    if activation == "silu":
        up = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * up
    elif activation == "relu2":
        up = jnp.square(jax.nn.relu(up))
    elif activation == "gelu":
        up = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return up @ p["w_down"].astype(x.dtype)


# ------------------------------------------------------------- embedding ----
def init_embed(key: Array, cfg: ArchConfig) -> dict:
    """Untied tables are named 'tok' (sharded on d: a gather over an
    unsharded vocab dim keeps the batch sharding of its output — a gather
    over a sharded vocab dim makes GSPMD replicate everything downstream).
    Tied tables ('tok_tied') shard on vocab for the unembed matmul."""
    k1, k2 = jax.random.split(key)
    table = (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02
             ).astype(cfg.param_dtype)
    p = {"ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype)}
    if cfg.tie_embeddings:
        p["tok_tied"] = table
    else:
        p["tok"] = table
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab)) * 0.02
                        ).astype(cfg.param_dtype)
    return p


def _tok_table(p: dict) -> Array:
    return p["tok_tied"] if "tok_tied" in p else p["tok"]


def embed(p: dict, tokens: Array, cfg: ArchConfig) -> Array:
    x = jnp.take(_tok_table(p), tokens, axis=0).astype(cfg.compute_dtype)
    return constrain_act(x)


def unembed(p: dict, x: Array, cfg: ArchConfig) -> Array:
    x = rmsnorm(x, p["ln_f"], cfg.rms_eps)
    w = p["unembed"] if "unembed" in p else _tok_table(p).T
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def softmax_xent(logits: Array, labels: Array, mode: str = "gather") -> Array:
    if mode == "onehot":
        # vocab-sharding-safe: no take_along_axis over the sharded V dim
        # (which GSPMD turns into a full logits all-gather).  The masked sum
        # reduces over the sharded dim -> one tiny psum of (B, S).
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        v = logits.shape[-1]
        onehot = labels[..., None] == jnp.arange(v)[None, None, :]
        picked = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
        return jnp.mean(lse - picked)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
