"""The paper's DNN: 3-layer MLP (784 -> 128 -> 64 -> 10), ReLU, softmax-CE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

LAYERS = [(784, 128), (128, 64), (64, 10)]


def init_params(key: Array, layers=None) -> dict:
    layers = layers or LAYERS
    params = {}
    keys = jax.random.split(key, len(layers))
    for i, ((fan_in, fan_out), k) in enumerate(zip(layers, keys)):
        params[f"w{i}"] = jax.random.normal(k, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params[f"b{i}"] = jnp.zeros((fan_out,))
    return params


def apply(params: dict, x: Array) -> Array:
    n_layers = len([k for k in params if k.startswith("w")])
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params: dict, x: Array, y: Array) -> Array:
    logits = apply(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(params: dict, x: Array, y: Array) -> Array:
    return jnp.mean((jnp.argmax(apply(params, x), axis=-1) == y).astype(jnp.float32))


def num_params(layers=None) -> int:
    layers = layers or LAYERS
    return sum(i * o + o for i, o in layers)
