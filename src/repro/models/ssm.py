"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD training/prefill path and O(1)-state decode path.  The chunked
algorithm computes, per chunk of length Q:
  intra-chunk: quadratic (masked-decay) attention-like term,
  chunk state:  sum_k exp(l_end - l_k) dt_k B_k (x) x_k,
  inter-chunk: a lax.scan carrying the (B, H, P, N) SSM state.
Decode carries (conv buffer, SSM state) per layer — constant memory in
sequence length, which is what qualifies this family for long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig

Array = jax.Array


# ----------------------------------------------------------- layer params ---
def init_mamba(key: Array, cfg: ArchConfig) -> dict:
    """Projections are kept SEPARATE (z/x/B/C/dt + three depthwise convs)
    rather than fused as in the reference CUDA kernels: fused projections put
    semantic split points mid-shard under tensor parallelism, forcing GSPMD
    reshards.  Separate weights shard cleanly (di by 'model', d by 'fsdp')."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.state_dim
    conv_ch = di + 2 * gn
    ks = jax.random.split(key, 8)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "w_z": (jax.random.normal(ks[0], (d, di)) * scale).astype(cfg.param_dtype),
        "w_x": (jax.random.normal(ks[1], (d, di)) * scale).astype(cfg.param_dtype),
        "w_b": (jax.random.normal(ks[2], (d, gn)) * scale).astype(cfg.param_dtype),
        "w_c": (jax.random.normal(ks[3], (d, gn)) * scale).astype(cfg.param_dtype),
        "w_dt": (jax.random.normal(ks[4], (d, nh)) * scale).astype(cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[5], (s.conv_width, conv_ch)) * 0.1
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.param_dtype),
        "d_skip": jnp.ones((nh,), cfg.param_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.param_dtype),
        "norm": jnp.zeros((di,), cfg.param_dtype),
        "out_proj": (jax.random.normal(ks[6], (di, d)) / jnp.sqrt(di)
                     ).astype(cfg.param_dtype),
    }


def _split_proj(p, u, cfg):
    """Returns (z, xbc_preconv_concat, dt_raw).  xbc stays concatenated only
    for the depthwise conv + decode conv-buffer layout (channel-wise op)."""
    z = u @ p["w_z"].astype(u.dtype)
    x = u @ p["w_x"].astype(u.dtype)
    b = u @ p["w_b"].astype(u.dtype)
    c = u @ p["w_c"].astype(u.dtype)
    dt = u @ p["w_dt"].astype(u.dtype)
    return z, jnp.concatenate([x, b, c], axis=-1), dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + pad[:, i: i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def ssd_scan(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
             chunk: int, init_state: Array | None = None):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); b,c: (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).  All math in f32.

    TPU note: the intra-chunk quadratic term below materializes a
    (B,NC,Q,Q,H) decay tensor through HBM; repro.kernels.ssd implements the
    same computation as a Pallas kernel that keeps the decay matrix in VMEM
    (validated vs both oracles in tests/test_ssd_kernel.py) — the drop-in
    replacement for y_intra on real hardware.
    """
    bsz, s, h, pdim = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    f32 = jnp.float32
    x_ = x.reshape(bsz, nc, q, h, pdim).astype(f32)
    dt_ = dt.reshape(bsz, nc, q, h).astype(f32)
    b_ = b.reshape(bsz, nc, q, g, n).astype(f32)
    c_ = c.reshape(bsz, nc, q, g, n).astype(f32)
    a = -jnp.exp(a_log.astype(f32))                       # (H,) negative
    da = dt_ * a[None, None, None, :]                     # (B,NC,Q,H) log-decay
    la = jnp.cumsum(da, axis=2)                           # cumulative within chunk

    # intra-chunk (masked decay "attention"):
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]     # (B,NC,Q,K,H) l_t - l_k
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask the EXPONENT (not the exp) so the masked upper triangle never
    # overflows — exp(+big) would poison the where-gradient with 0 * inf.
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcqgn,bckgn->bcqkg", c_, b_)         # (B,NC,Q,K,G)
    xh = x_.reshape(bsz, nc, q, g, hg, pdim)
    dth = dt_.reshape(bsz, nc, q, g, hg)
    dech = decay.reshape(bsz, nc, q, q, g, hg)
    y_intra = jnp.einsum("bcqkg,bcqkgh,bckgh,bckghp->bcqghp",
                         cb, dech, dth, xh)

    # chunk states: S_c = sum_k exp(l_end - l_k) dt_k B_k (x) x_k
    end_decay = jnp.exp(la[:, :, -1:, :] - la)            # (B,NC,Q,H)
    edh = end_decay.reshape(bsz, nc, q, g, hg)
    s_c = jnp.einsum("bckgn,bckgh,bckgh,bckghp->bcghpn", b_, edh, dth, xh)

    # inter-chunk scan
    chunk_decay = jnp.exp(la[:, :, -1, :])                # (B,NC,H)
    cdh = chunk_decay.reshape(bsz, nc, g, hg)
    h0 = (jnp.zeros((bsz, g, hg, pdim, n), f32) if init_state is None
          else init_state.reshape(bsz, g, hg, pdim, n).astype(f32))

    def body(state, inp):
        s_chunk, cd = inp  # (B,G,HG,P,N), (B,G,HG)
        new = state * cd[..., None, None] + s_chunk
        return new, state  # emit state BEFORE this chunk

    last, prev_states = jax.lax.scan(
        body, h0, (s_c.transpose(1, 0, 2, 3, 4, 5), cdh.transpose(1, 0, 2, 3)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)  # (B,NC,G,HG,P,N)

    in_decay = jnp.exp(la).reshape(bsz, nc, q, g, hg)
    y_inter = jnp.einsum("bcqgn,bcqgh,bcghpn->bcqghp", c_, in_decay, prev_states)

    y = (y_intra + y_inter).reshape(bsz, nc * q, h, pdim)[:, :s]
    return y.astype(x.dtype), last.reshape(bsz, h, pdim, n)


def mamba_block(p: dict, u: Array, cfg: ArchConfig,
                init_state: Array | None = None, return_state: bool = False):
    """Full mamba2 block. u: (B, S, d) -> (B, S, d)."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.state_dim
    z, xbc, dt = _split_proj(p, u, cfg)
    xbc = _causal_conv(xbc, p["conv_w"].astype(u.dtype), p["conv_b"].astype(u.dtype))
    xin = xbc[..., :di]
    b = xbc[..., di: di + gn].reshape(*u.shape[:2], s.n_groups, s.state_dim)
    c = xbc[..., di + gn:].reshape(*u.shape[:2], s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(*u.shape[:2], nh, s.head_dim)
    y, state = ssd_scan(xh, dt, p["a_log"], b, c, s.chunk, init_state)
    y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*u.shape[:2], di)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    if return_state:
        return out, state
    return out


# ------------------------------------------------------------- decode -------
def mamba_decode(p: dict, u: Array, cfg: ArchConfig, conv_buf: Array,
                 state: Array):
    """One-token step. u: (B, 1, d); conv_buf: (B, W-1, C); state: (B,H,P,N)."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.state_dim
    z, xbc, dt = _split_proj(p, u, cfg)
    # conv via buffer
    window = jnp.concatenate([conv_buf, xbc], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window,
                          p["conv_w"].astype(u.dtype)) + p["conv_b"].astype(u.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_buf = window[:, 1:]
    xin = conv_out[..., :di]
    b = conv_out[..., di: di + gn].reshape(-1, s.n_groups, s.state_dim)
    c = conv_out[..., di + gn:].reshape(-1, s.n_groups, s.state_dim)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a[None])                              # (B, H)
    xh = xin[:, 0].reshape(-1, nh, s.head_dim).astype(jnp.float32)
    g, hg = s.n_groups, nh // s.n_groups
    xg = xh.reshape(-1, g, hg, s.head_dim)
    dtg = dt1.reshape(-1, g, hg)
    stg = state.reshape(-1, g, hg, s.head_dim, s.state_dim).astype(jnp.float32)
    upd = jnp.einsum("bgn,bgh,bghp->bghpn", b.astype(jnp.float32), dtg, xg)
    stg = stg * decay.reshape(-1, g, hg)[..., None, None] + upd
    y = jnp.einsum("bgn,bghpn->bghp", c.astype(jnp.float32), stg)
    y = y.reshape(-1, nh, s.head_dim) + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, di).astype(u.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    return out, new_buf, stg.reshape(-1, nh, s.head_dim, s.state_dim)


# --------------------------------------------------------------- model ------
def _stack(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init(key: Array, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "embed": L.init_embed(k1, cfg),
        "blocks": {
            "mamba": _stack(k2, cfg.n_layers, lambda k: init_mamba(k, cfg)),
            "ln": jnp.zeros((cfg.n_layers, cfg.d_model), cfg.param_dtype),
        },
    }


def forward(params: dict, tokens: Array, cfg: ArchConfig) -> Array:
    x = L.embed(params["embed"], tokens, cfg)

    def body(x, blk):
        def f(x):
            h = L.rmsnorm(x, blk["ln"], cfg.rms_eps)
            return x + mamba_block(blk["mamba"], h, cfg)
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(x), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    x = forward(params, batch["tokens"], cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return L.softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int = 0, dtype=None) -> dict:
    """SSM 'cache' = conv buffer + state per layer; independent of max_seq."""
    dtype = dtype or cfg.compute_dtype
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.state_dim
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((cfg.n_layers, batch, nh, s.head_dim, s.state_dim),
                           jnp.float32),
    }


def prefill(params: dict, tokens: Array, cfg: ArchConfig):
    x = L.embed(params["embed"], tokens, cfg)
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.state_dim

    def body(x, blk):
        h = L.rmsnorm(x, blk["ln"], cfg.rms_eps)
        # recompute the conv tail for the decode buffer
        z, xbc, dt = _split_proj(blk["mamba"], h, cfg)
        out, state = mamba_block(blk["mamba"], h, cfg, return_state=True)
        conv_tail = xbc[:, -(s.conv_width - 1):, :]
        return x + out, (conv_tail, state)

    x, (convs, states) = jax.lax.scan(body, x, params["blocks"])
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"conv": convs, "state": states}


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    x = L.embed(params["embed"], token[:, None], cfg)

    def body(x, inp):
        blk, conv_buf, state = inp
        h = L.rmsnorm(x, blk["ln"], cfg.rms_eps)
        out, new_buf, new_state = mamba_decode(blk["mamba"], h, cfg, conv_buf,
                                               state)
        return x + out, (new_buf, new_state)

    x, (convs, states) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["state"]))
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"conv": convs, "state": states}
