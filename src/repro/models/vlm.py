"""LLaVA-NeXT-style VLM backbone (Mistral-7B LM + anyres patch embeddings).

The vision tower + projector is STUBBED per the brief: the data pipeline /
input_specs supply pre-projected patch embeddings (B, n_patches, d_model).
Early fusion: patch embeddings are prepended to the token embeddings and the
dense decoder runs over the fused sequence; the LM loss covers text positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dense
from .config import ArchConfig

Array = jax.Array

init = dense.init


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    return dense.loss_fn(params, batch, cfg)  # dense handles batch["patches"]


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    # cache must also cover the patch positions
    return dense.init_cache(cfg, batch, max_seq + cfg.n_patches, dtype)


def prefill(params: dict, batch: dict, cfg: ArchConfig):
    return dense.prefill(params, batch["tokens"], cfg,
                         extra_embeds=batch["patches"])


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    """pos counts the fused sequence (patches + text)."""
    return dense.decode_step(params, token, cache, pos, cfg)
