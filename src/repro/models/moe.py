"""Mixture-of-Experts layer: top-k router + capacity-bounded sort-based dispatch.

Design (TPU-native, GSPMD-friendly):
  * tokens are processed in independent dispatch groups (the leading batch/
    shard dim), so routing state never crosses the data sharding boundary;
  * within a group, slots are assigned to experts by a stable sort of expert
    ids (O(N log N) int ops, no (tokens x experts) one-hot matmuls and none of
    their fake FLOPs);
  * each expert processes a fixed capacity C = ceil(T/E * k * capacity_factor)
    of slots — overflow drops (standard Switch/Mixtral semantics);
  * expert weights are stacked (E, d, ff) and meant to be sharded over the
    'model' mesh axis (expert parallelism).  The dispatch buffer is sliced
    along E by GSPMD for free (it's replicated across 'model' post-scatter),
    and the combine scatter-add produces partial token outputs that XLA
    reduces across the model axis.
  * aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig, MoEConfig

Array = jax.Array


def init_moe(key: Array, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(ff)
    p = {
        "router": (jax.random.normal(ks[0], (d, m.num_experts)) * s_in
                   ).astype(cfg.param_dtype),
        "w_up": (jax.random.normal(ks[1], (m.num_experts, d, ff)) * s_in
                 ).astype(cfg.param_dtype),
        "w_down": (jax.random.normal(ks[2], (m.num_experts, ff, d)) * s_out
                   ).astype(cfg.param_dtype),
    }
    if cfg.activation == "silu":
        p["w_gate"] = (jax.random.normal(ks[3], (m.num_experts, d, ff)) * s_in
                       ).astype(cfg.param_dtype)
    if m.shared_expert_ff:
        from .layers import init_mlp

        p["shared"] = init_mlp(ks[4], d, m.shared_expert_ff, cfg.activation,
                               cfg.param_dtype)
    return p


def _dispatch_group(x: Array, expert_ids: Array, gates: Array, capacity: int,
                    num_experts: int):
    """One dispatch group.  x: (T, d); expert_ids/gates: (T, k).

    Returns (buffer (E*C, d), dest (T*k,), keep (T*k,), tok (T*k,), gate (T*k,)).
    """
    t, k = expert_ids.shape
    n = t * k
    ids = expert_ids.reshape(n)
    tok = jnp.repeat(jnp.arange(t), k)
    g = gates.reshape(n)
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(num_experts), side="left")
    rank = jnp.arange(n) - starts[sorted_ids]
    keep = rank < capacity
    dest = jnp.where(keep, sorted_ids * capacity + jnp.clip(rank, 0, capacity - 1), 0)
    buffer = jnp.zeros((num_experts * capacity, x.shape[-1]), x.dtype)
    src = x[tok[order]]
    src = jnp.where(keep[:, None], src, 0)
    buffer = buffer.at[dest].add(src)  # add: dropped slots all alias dest 0 with 0 value
    return buffer, dest, keep, tok[order], g[order]


def moe_apply(p: dict, x: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)  # (B, S, k)
    gates = top_p / jnp.clip(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # aux losses
    density = jnp.mean(probs, axis=(0, 1))                        # (E,)
    frac = jnp.mean(jax.nn.one_hot(top_ids[..., 0], e), axis=(0, 1))
    lb_loss = e * jnp.sum(density * frac) * m.load_balance_coef
    z_loss = m.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    capacity = max(int(s * k * m.capacity_factor / e), 1)

    def per_group(xg, idg, gg):
        buf, dest, keep, tok, gate = _dispatch_group(xg, idg, gg, capacity, e)
        buf = buf.reshape(e, capacity, d)
        up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
        if "w_gate" in p:
            gt = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
            up = jax.nn.silu(gt) * up
        elif cfg.activation == "relu2":
            up = jnp.square(jax.nn.relu(up))
        else:
            up = jax.nn.gelu(up)
        out_buf = jnp.einsum("ecf,efd->ecd", up, p["w_down"].astype(buf.dtype))
        out_buf = out_buf.reshape(e * capacity, d)
        contrib = out_buf[dest] * (gate * keep)[:, None].astype(buf.dtype)
        out = jnp.zeros((xg.shape[0], d), x.dtype).at[tok].add(contrib)
        return out

    out = jax.vmap(per_group)(x, top_ids, gates)
    if "shared" in p:
        from .layers import mlp

        out = out + mlp(p["shared"], x, cfg.activation)
    return out, lb_loss + z_loss
