"""Whisper-style encoder-decoder (audio backbone, arXiv:2212.04356).

The mel-spectrogram + conv feature extractor frontend is STUBBED per the
brief: `input_specs` / the data pipeline supply pre-computed frame embeddings
(B, T_enc, d).  This module implements everything downstream: sinusoidal
positions, bidirectional encoder, causal decoder with cross-attention,
KV-cached decode (self-attn cache; cross K/V computed once at prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig

Array = jax.Array


def _sinusoid(seq: int, d: int) -> Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _stack(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init(key: Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    return {
        "embed": L.init_embed(ks[0], cfg),
        "encoder": {
            "attn": _stack(ks[1], cfg.encoder_layers, lambda k: L.init_attn(k, cfg)),
            "mlp": _stack(ks[2], cfg.encoder_layers,
                          lambda k: L.init_mlp(k, d, cfg.d_ff, "gelu",
                                               cfg.param_dtype)),
            "ln1": jnp.zeros((cfg.encoder_layers, d), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.encoder_layers, d), cfg.param_dtype),
        },
        "enc_ln_f": jnp.zeros((d,), cfg.param_dtype),
        "decoder": {
            "attn": _stack(ks[3], cfg.n_layers, lambda k: L.init_attn(k, cfg)),
            "xattn": _stack(ks[4], cfg.n_layers, lambda k: L.init_attn(k, cfg)),
            "mlp": _stack(ks[5], cfg.n_layers,
                          lambda k: L.init_mlp(k, d, cfg.d_ff, "gelu",
                                               cfg.param_dtype)),
            "ln1": jnp.zeros((cfg.n_layers, d), cfg.param_dtype),
            "lnx": jnp.zeros((cfg.n_layers, d), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.n_layers, d), cfg.param_dtype),
        },
    }


def encode(params: dict, frames: Array, cfg: ArchConfig) -> Array:
    """frames: (B, T_enc, d) pre-embedded (conv frontend stub)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, blk):
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        # bidirectional: no causal mask -> use cross_attention on itself
        x = x + L.cross_attention(blk["attn"], h, h, cfg)
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(x, params["enc_ln_f"], cfg.rms_eps)


def decode_train(params: dict, enc: Array, tokens: Array, cfg: ArchConfig) -> Array:
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, blk):
        def f(x):
            h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
            x = x + L.attention(blk["attn"], h, cfg, positions)
            h = L.rmsnorm(x, blk["lnx"], cfg.rms_eps)
            x = x + L.cross_attention(blk["xattn"], h, enc, cfg)
            h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
            return x + L.mlp(blk["mlp"], h, "gelu")
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(x), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    return x


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    enc = encode(params, batch["frames"], cfg)
    x = decode_train(params, enc, batch["tokens"], cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return L.softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.padded_kv_heads(), cfg.dh), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.padded_kv_heads(), cfg.dh), dtype),
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.encoder_frames,
                         cfg.padded_kv_heads(), cfg.dh), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.encoder_frames,
                         cfg.padded_kv_heads(), cfg.dh), dtype),
    }


def prefill(params: dict, batch: dict, cfg: ArchConfig):
    """Encode frames + run decoder prompt; returns (logits, cache)."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, blk):
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        q, k, v = L._qkv(blk["attn"], h, cfg, positions)
        out = L._sdpa_blocked(q, k, v, positions, positions, 0, cfg.attn_q_block)
        x = x + L.proj_out(blk["attn"], out, cfg)
        h = L.rmsnorm(x, blk["lnx"], cfg.rms_eps)
        xk = jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wk"].astype(x.dtype))
        xv = jnp.einsum("bsd,dhk->bshk", enc, blk["xattn"]["wv"].astype(x.dtype))
        x = x + L.cross_attention(blk["xattn"], h, enc, cfg)
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, "gelu")
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"])
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    x = L.embed(params["embed"], token[:, None], cfg)

    def body(x, inp):
        blk, ck, cv, xk, xv = inp
        h = L.rmsnorm(x, blk["ln1"], cfg.rms_eps)
        out, ck, cv = L.attention_decode(blk["attn"], h, cfg, ck, cv, pos)
        x = x + out
        h = L.rmsnorm(x, blk["lnx"], cfg.rms_eps)
        # cross-attn against precomputed enc K/V
        q = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"].astype(x.dtype))
        kvh = xk.shape[2]
        groups = q.shape[2] // kvh
        qg = q.reshape(q.shape[0], 1, kvh, groups, cfg.dh)
        lg = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        xk.astype(jnp.float32)) / jnp.sqrt(cfg.dh)
        w = jax.nn.softmax(lg, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(xv.dtype), xv)
        out = out.reshape(q.shape[0], 1, -1, cfg.dh)
        x = x + L.proj_out(blk["xattn"], out, cfg)
        h = L.rmsnorm(x, blk["ln2"], cfg.rms_eps)
        x = x + L.mlp(blk["mlp"], h, "gelu")
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]))
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
