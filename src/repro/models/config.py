"""Architecture configuration shared by every model family."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    shared_expert_ff: int = 0     # 0 => no shared expert
    moe_every: int = 1            # 1 => every layer is MoE; 2 => alternate dense/MoE
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qkv_bias: bool = False
    activation: str = "silu"      # silu (gated) | relu2 (squared relu) | gelu
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # sliding window: per-layer pattern. window 0 => full attention.
    sliding_window: int = 0
    global_every: int = 0         # gemma3: 1 global layer every N (others local)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0           # zamba2: shared attention block every N ssm layers
    # enc-dec (audio): encoder frames arrive pre-embedded (conv frontend stub)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # vlm: patch embeddings arrive pre-projected (vision tower stub)
    n_patches: int = 0
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    attn_q_block: int = 1024      # query-block size for flash-style attention
    remat: bool = True            # checkpoint each block in training
    scan_layers: bool = True
    # --- perf toggles (see EXPERIMENTS.md §Perf; defaults = paper baseline) ---
    attn_scan_remat: bool = False  # rematerialize per-q-block scores in bwd
    xent_mode: str = "gather"      # 'gather' | 'onehot' (vocab-sharded safe)
    head_pad: int = 0              # pad MHA head count up to a multiple of
                                   # this (16 = model axis) so heads shard;
                                   # padded heads are output-masked (exact).
                                   # Applied only when n_heads == n_kv_heads.

    def padded_heads(self) -> int:
        h = self.n_heads
        if (self.head_pad and self.n_heads == self.n_kv_heads
                and h % self.head_pad):
            return -(-h // self.head_pad) * self.head_pad
        return h

    def padded_kv_heads(self) -> int:
        if self.padded_heads() != self.n_heads:
            return self.padded_heads()
        return self.n_kv_heads

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def window_for_layer(self, layer: int) -> int:
        """0 = full attention; >0 = causal sliding window size."""
        if self.global_every and (layer + 1) % self.global_every != 0:
            return self.sliding_window
        if self.global_every:
            return 0
        return self.sliding_window

    def supports_long_context(self) -> bool:
        """Can this arch decode at 500k+ without a quadratic/full KV path?"""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense with sliding window on most layers (gemma3 5:1)
        return bool(self.sliding_window and self.global_every)

    def has_decoder(self) -> bool:
        return True  # all assigned archs are (or contain) decoders


def num_params(cfg: ArchConfig) -> int:
    """Analytic parameter count (matches init shapes; used for 6ND roofline)."""
    d = cfg.d_model
    dh = cfg.dh if cfg.n_heads else 0
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    attn = d * (cfg.n_heads * dh) + 2 * d * (cfg.n_kv_heads * dh) + (cfg.n_heads * dh) * d
    if cfg.qkv_bias:
        attn += (cfg.n_heads + 2 * cfg.n_kv_heads) * dh
    def mlp_params(ff, act):
        return d * ff * (3 if act == "silu" else 2)
    total = emb
    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn + mlp_params(cfg.d_ff, cfg.activation) + 2 * d)
    elif cfg.family == "moe":
        m = cfg.moe
        expert = mlp_params(m.d_ff_expert, cfg.activation)
        moe_layer = attn + m.num_experts * expert + d * m.num_experts + 2 * d
        if m.shared_expert_ff:
            moe_layer += mlp_params(m.shared_expert_ff, cfg.activation)
        n_moe = cfg.n_layers // m.moe_every
        n_dense = cfg.n_layers - n_moe
        total += n_moe * moe_layer
        total += n_dense * (attn + mlp_params(cfg.d_ff, cfg.activation) + 2 * d)
    elif cfg.family == "ssm":
        total += cfg.n_layers * _mamba_params(cfg)
    elif cfg.family == "hybrid":
        total += cfg.n_layers * _mamba_params(cfg)
        total += attn + mlp_params(cfg.d_ff, cfg.activation) + 2 * d  # shared block
    elif cfg.family == "audio":
        enc_layer = attn + mlp_params(cfg.d_ff, "gelu") + 2 * d
        dec_layer = 2 * attn + mlp_params(cfg.d_ff, "gelu") + 3 * d  # self+cross
        total += cfg.encoder_layers * enc_layer + cfg.n_layers * dec_layer
    return total


def _mamba_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.state_dim
    in_proj = d * (2 * di + 2 * s.n_groups * s.state_dim + nh)
    return in_proj + conv_ch * s.conv_width + nh * 2 + di + di * d + d


def num_active_params(cfg: ArchConfig) -> int:
    """Active (per-token) parameters — MoE counts only top_k experts."""
    if cfg.family != "moe":
        return num_params(cfg)
    m = cfg.moe
    d = cfg.d_model
    expert = d * m.d_ff_expert * (3 if cfg.activation == "silu" else 2)
    total = num_params(cfg)
    n_moe = cfg.n_layers // m.moe_every
    total -= n_moe * (m.num_experts - m.top_k) * expert
    return total
