"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every `attn_every` layers (arXiv:2411.15242).

The shared block's weights are reused at every invocation (parameter-efficient)
but each invocation keeps its own KV cache.  A sliding window bounds the
attention state so the hybrid still qualifies for long_500k decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from .ssm import init_mamba, mamba_block, mamba_decode

Array = jax.Array


def _n_periods(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init(key: Array, cfg: ArchConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_p = _n_periods(cfg)
    per = cfg.attn_every

    def period_init(k):
        ks = jax.random.split(k, per)
        return jax.vmap(lambda kk: init_mamba(kk, cfg))(ks)

    return {
        "embed": L.init_embed(k1, cfg),
        "blocks": {
            "mamba": jax.vmap(period_init)(jax.random.split(k2, n_p)),
            "ln": jnp.zeros((n_p, per, cfg.d_model), cfg.param_dtype),
        },
        "shared": {
            "attn": L.init_attn(k3, cfg),
            "mlp": L.init_mlp(k4, cfg.d_model, cfg.d_ff, cfg.activation,
                              cfg.param_dtype),
            "ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        },
    }


def _shared_attn(params, x, cfg, positions):
    sh = params["shared"]
    h = L.rmsnorm(x, sh["ln1"], cfg.rms_eps)
    x = x + L.attention(sh["attn"], h, cfg, positions, window=cfg.sliding_window)
    h = L.rmsnorm(x, sh["ln2"], cfg.rms_eps)
    return x + L.mlp(sh["mlp"], h, cfg.activation)


def forward(params: dict, tokens: Array, cfg: ArchConfig) -> Array:
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def period(x, blk):
        def f(x):
            x = _shared_attn(params, x, cfg, positions)

            def inner(x, lyr):
                h = L.rmsnorm(x, lyr["ln"], cfg.rms_eps)
                return x + mamba_block(lyr["mamba"], h, cfg), None

            x, _ = jax.lax.scan(inner, x, blk)
            return x
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(x), None

    x, _ = jax.lax.scan(period, x, params["blocks"])
    return x


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    x = forward(params, batch["tokens"], cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return L.softmax_xent(logits, batch["labels"], mode=cfg.xent_mode)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    s = cfg.ssm
    n_p = _n_periods(cfg)
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.n_groups * s.state_dim
    kv_seq = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    return {
        "k": jnp.zeros((n_p, batch, kv_seq, cfg.n_kv_heads, cfg.dh), dtype),
        "v": jnp.zeros((n_p, batch, kv_seq, cfg.n_kv_heads, cfg.dh), dtype),
        "conv": jnp.zeros((n_p, cfg.attn_every, batch, s.conv_width - 1, conv_ch),
                          dtype),
        "state": jnp.zeros((n_p, cfg.attn_every, batch, nh, s.head_dim,
                            s.state_dim), jnp.float32),
    }


def prefill(params: dict, tokens: Array, cfg: ArchConfig, max_seq: int = 0):
    """Prefill; the KV ring buffer is sized for the DECODE horizon:
    win = min(max_seq or prefill_len, sliding_window)."""
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    horizon = max(max_seq, s)
    win = min(horizon, cfg.sliding_window) if cfg.sliding_window else horizon
    sh = params["shared"]
    ssm_cfg = cfg.ssm

    def period(x, blk):
        h = L.rmsnorm(x, sh["ln1"], cfg.rms_eps)
        q, k, v = L._qkv(sh["attn"], h, cfg, positions)
        out = L._sdpa_blocked(q, k, v, positions, positions,
                              cfg.sliding_window, cfg.attn_q_block)
        x = x + jnp.einsum("bshk,hkd->bsd", out, sh["attn"]["wo"].astype(x.dtype))
        h = L.rmsnorm(x, sh["ln2"], cfg.rms_eps)
        x = x + L.mlp(sh["mlp"], h, cfg.activation)

        def inner(x, lyr):
            h = L.rmsnorm(x, lyr["ln"], cfg.rms_eps)
            from .ssm import _split_proj
            _, xbc, _ = _split_proj(lyr["mamba"], h, cfg)
            out, state = mamba_block(lyr["mamba"], h, cfg, return_state=True)
            return x + out, (xbc[:, -(ssm_cfg.conv_width - 1):, :], state)

        x, (convs, states) = jax.lax.scan(inner, x, blk)
        # ring-buffer layout: slot (p % win) must hold position p so decode's
        # overwrite at slot pos%win replaces the oldest entry.
        if s <= win:
            k_tail = jnp.pad(k, ((0, 0), (0, win - s), (0, 0), (0, 0)))
            v_tail = jnp.pad(v, ((0, 0), (0, win - s), (0, 0), (0, 0)))
        else:
            k_tail = jnp.roll(k[:, -win:], shift=s % win, axis=1)
            v_tail = jnp.roll(v[:, -win:], shift=s % win, axis=1)
        return x, (k_tail, v_tail, convs, states)

    x, (ks, vs, convs, states) = jax.lax.scan(period, x, params["blocks"])
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"k": ks, "v": vs, "conv": convs, "state": states}


def decode_step(params: dict, token: Array, cache: dict, pos: Array,
                cfg: ArchConfig):
    """Decode. KV cache is a ring buffer of size window when sliding."""
    x = L.embed(params["embed"], token[:, None], cfg)
    win = cache["k"].shape[2]
    sh = params["shared"]
    # ring-buffer slot + effective positions of cached keys handled by storing
    # absolute positions alongside is overkill here: with window w the cache
    # holds positions pos-w+1..pos; we rotate so slot = pos % w.
    slot = pos % win

    def period(x, inp):
        blk, ck, cv, conv, state = inp
        h = L.rmsnorm(x, sh["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, sh["attn"]["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, sh["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, sh["attn"]["wv"].astype(h.dtype))
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
        onehot = (jnp.arange(win)[None] == slot[:, None]).astype(ck.dtype)
        ck = ck * (1 - onehot[..., None, None]) + onehot[..., None, None] * k
        cv = cv * (1 - onehot[..., None, None]) + onehot[..., None, None] * v
        kvh = ck.shape[2]
        groups = cfg.n_heads // kvh
        qg = q.reshape(-1, 1, kvh, groups, cfg.dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                            ck.astype(jnp.float32)) / jnp.sqrt(cfg.dh)
        # valid = slots already written (pos+1 entries, capped by win)
        valid = jnp.arange(win)[None] < jnp.minimum(pos[:, None] + 1, win)
        logits = jnp.where(valid[:, None, None, None, :], logits, L.NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(cv.dtype), cv)
        out = out.reshape(-1, 1, cfg.n_heads, cfg.dh)
        x = x + jnp.einsum("bshk,hkd->bsd", out, sh["attn"]["wo"].astype(x.dtype))
        h = L.rmsnorm(x, sh["ln2"], cfg.rms_eps)
        x = x + L.mlp(sh["mlp"], h, cfg.activation)

        def inner(x, lyr_inp):
            lyr, cbuf, st = lyr_inp
            h = L.rmsnorm(x, lyr["ln"], cfg.rms_eps)
            out, nbuf, nst = mamba_decode(lyr["mamba"], h, cfg, cbuf, st)
            return x + out, (nbuf, nst)

        x, (nconvs, nstates) = jax.lax.scan(inner, x, (blk, conv, state))
        return x, (ck, cv, nconvs, nstates)

    x, (ks, vs, convs, states) = jax.lax.scan(
        period, x, (params["blocks"], cache["k"], cache["v"], cache["conv"],
                    cache["state"]))
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs, "conv": convs, "state": states}
