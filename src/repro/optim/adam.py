"""Minimal Adam / SGD optimizers (no external deps), pytree-native."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: Array


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params: Any) -> AdamState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(self, grads: Any, state: AdamState, params: Any, lr_scale=1.0):
        count = state.count + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - self.b1**c
        bc2 = 1.0 - self.b2**c
        mu = jax.tree.map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * lr_scale * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(mu=mu, nu=nu, count=count)


@dataclasses.dataclass(frozen=True)
class SGDOpt:
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params: Any) -> Any:
        if not self.momentum:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(self, grads: Any, state: Any, params: Any, lr_scale=1.0):
        if not self.momentum:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - self.lr * lr_scale * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, state
        vel = jax.tree.map(
            lambda v, g: self.momentum * v + g.astype(jnp.float32), state, grads)
        new = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32)
                          - self.lr * lr_scale * v).astype(p.dtype), params, vel)
        return new, vel
