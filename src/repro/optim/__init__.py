from .adam import Adam, AdamState, SGDOpt
from .schedules import constant, cosine, linear_warmup_cosine

__all__ = ["Adam", "AdamState", "SGDOpt", "constant", "cosine", "linear_warmup_cosine"]
