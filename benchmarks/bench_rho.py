"""Paper Fig. 7: sensitivity to the disagreement penalty rho."""
from __future__ import annotations

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import gadmm  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402

from .bench_linreg import REL_TARGET  # noqa: E402
from .common import linreg_problem, rounds_to, run_gadmm_curve  # noqa: E402


def run(rhos=(2.0, 7.0, 24.0, 100.0), iters=400, bits=4, quick=False):
    if quick:
        rhos = (2.0, 24.0)
    xs, ys, xtx, xty, theta_star = linreg_problem()
    from repro.core.baselines import PSProblem

    prob = PSProblem(xtx=xtx, xty=xty)
    target = REL_TARGET * abs(float(prob.objective(theta_star)))
    rows = []
    for rho in rhos:
        for name, quant in (("GADMM", False), ("Q-GADMM", True)):
            cfg = gadmm.GADMMConfig(rho=rho, quantize=quant,
                                    qcfg=QuantizerConfig(bits=bits))
            losses, _ = run_gadmm_curve(xs, ys, cfg, iters, theta_star)
            rows.append(dict(alg=name, rho=rho,
                             rounds=rounds_to(losses, target),
                             final=float(losses[-1])))
    return rows


def main(quick=False):
    for r in run(quick=quick):
        print(f"fig7_rho_{r['alg']}_rho{r['rho']:g},0,"
              f"rounds={r['rounds']};final_loss={r['final']:.3g}")


if __name__ == "__main__":
    main()
