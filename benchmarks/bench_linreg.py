"""Paper Fig. 2: linear regression — loss vs (a) communication rounds,
(b) transmitted bits, (c) consumed energy, for Q-GADMM / GADMM / GD / QGD /
ADIANA.  Run with x64 for loss floors below 1e-4 (|F| ~ 1e4)."""
from __future__ import annotations

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import gadmm  # noqa: E402
from repro.core.baselines import PSProblem, run_adiana, run_gd  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402
from repro.core.topology import random_placement  # noqa: E402
from repro.core import comm_model as cm  # noqa: E402

from .common import linreg_problem, rounds_to, run_gadmm_curve  # noqa: E402

# The paper's 1e-4 ABSOLUTE threshold is specific to the California-housing
# objective scale; our synthetic stand-in uses the scale-free equivalent:
# |F - F*| <= 1e-5 * |F*|.
REL_TARGET = 1e-4


def run(n_workers=50, iters=600, rho=24.0, bits=2, seed=0, quick=False):
    if quick:
        n_workers, iters = 20, 300
    xs, ys, xtx, xty, theta_star = linreg_problem(n_workers=n_workers,
                                                  seed=seed)
    d = xs.shape[-1]
    prob = PSProblem(xtx=xtx, xty=xty)
    fstar_vec = jnp.broadcast_to(theta_star, (1, d))

    def ps_losses(thetas):
        f = jax.vmap(prob.objective)(thetas)
        fs = float(prob.objective(theta_star))
        return np.abs(np.asarray(f) - fs)

    curves, bits_per_round = {}, {}
    g_losses, _ = run_gadmm_curve(
        xs, ys, gadmm.GADMMConfig(rho=rho, quantize=False), iters, theta_star)
    curves["GADMM"] = g_losses
    bits_per_round["GADMM"] = gadmm.bits_per_round(
        gadmm.GADMMConfig(rho=rho, quantize=False), n_workers, d)

    for b_ in sorted({bits, 4}):
        qcfg = gadmm.GADMMConfig(rho=rho, quantize=True,
                                 qcfg=QuantizerConfig(bits=b_))
        q_losses, _ = run_gadmm_curve(xs, ys, qcfg, iters, theta_star)
        curves[f"Q-GADMM-{b_}b"] = q_losses
        bits_per_round[f"Q-GADMM-{b_}b"] = gadmm.bits_per_round(
            qcfg, n_workers, d)

    thetas, b = run_gd(prob, iters)
    curves["GD"] = ps_losses(thetas)
    bits_per_round["GD"] = b
    thetas, b = run_gd(prob, iters, quantize_bits=bits)
    curves["QGD"] = ps_losses(thetas)
    bits_per_round["QGD"] = b
    ys_ad, b = run_adiana(prob, iters, bits=bits)
    curves["ADIANA"] = ps_losses(ys_ad)
    bits_per_round["ADIANA"] = b

    # energy model (paper Sec. V-A)
    placement = random_placement(n_workers, seed=seed)
    radio = cm.RadioConfig(n_workers=n_workers)
    bd = placement.broadcast_dist()
    fstar = abs(float(prob.objective(theta_star)))
    target = REL_TARGET * fstar
    rows = []
    for name, losses in curves.items():
        r = rounds_to(losses, target)
        decentralized = "GADMM" in name
        per_worker_bits = bits_per_round[name] / n_workers
        if decentralized:
            e_round = cm.round_energy_decentralized(
                np.full(n_workers, per_worker_bits), bd, radio)
        else:
            up = (bits_per_round[name] - 32 * d) / n_workers
            e_round = cm.round_energy_ps(up, placement.ps_dist, 32 * d, radio)
        total_bits = r * bits_per_round[name]   # inf flows through a miss
        total_e = r * e_round
        rows.append(dict(alg=name, rounds_to_1e4=r,
                         bits_per_round=bits_per_round[name],
                         total_bits=total_bits, total_energy_J=total_e,
                         final_loss=float(losses[-1])))
    return rows, curves


def main(quick=False):
    rows, _ = run(quick=quick)
    base_bits = next(r for r in rows if r["alg"] == "GADMM")["total_bits"]
    for r in rows:
        derived = (f"rounds={r['rounds_to_1e4']};"
                   f"bits={r['total_bits']:.3g};"
                   f"bits_vs_GADMM={r['total_bits']/base_bits:.3f};"
                   f"energy_J={r['total_energy_J']:.3g}")
        print(f"fig2_linreg_{r['alg']},0,{derived}")


if __name__ == "__main__":
    main()
