"""Paper Fig. 8: per-iteration computation overhead of quantization
(Q-GADMM vs GADMM wall time, communication excluded), plus the fused-kernel
mitigation (Pallas interpret timings are indicative only on CPU)."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import gadmm
from repro.core.quantizer import QuantizerConfig

from .common import linreg_problem


def _time_steps(step, st, iters=50):
    st = step(st)  # compile
    jax.block_until_ready(st.theta)
    t0 = time.perf_counter()
    for _ in range(iters):
        st = step(st)
    jax.block_until_ready(st.theta)
    return (time.perf_counter() - t0) / iters * 1e6  # us/iter


def run(quick=False):
    n = 20 if quick else 50
    xs, ys, *_ = linreg_problem(n_workers=n)
    rows = []
    for name, cfg in [
        ("GADMM", gadmm.GADMMConfig(rho=24.0, quantize=False)),
        ("Q-GADMM", gadmm.GADMMConfig(rho=24.0, quantize=True,
                                      qcfg=QuantizerConfig(bits=2))),
    ]:
        q = gadmm.make_quadratic(xs, ys, cfg.rho)
        st = gadmm.init_state(n, xs.shape[-1], cfg)
        step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
        rows.append((name, _time_steps(step, st)))
    return rows


def main(quick=False):
    rows = run(quick=quick)
    base = rows[0][1]
    for name, us in rows:
        print(f"fig8_compute_{name},{us:.1f},overhead_vs_GADMM="
              f"{us/base:.3f}")


if __name__ == "__main__":
    main()
