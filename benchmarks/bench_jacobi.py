"""Beyond-paper variant validation: Jacobi vs Gauss-Seidel Q-GADMM.

§Perf i9 shows Jacobi mode halves every roofline term per step (one update of
all workers instead of two masked head/tail phases).  The trade-off is losing
the Gauss-Seidel ordering.  This benchmark measures the convergence side:
loss after equal NUMBERS OF STEPS and after equal COMPUTE (1 Jacobi step ~
half a G-S step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import QuantizerConfig
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
from repro.models import registry


def run(steps=24, quick=False):
    if quick:
        steps = 12
    cfg = registry.get_config("qwen1.5-4b", smoke=True)
    model = registry.get_model(cfg)
    from repro.launch.mesh import factor_mesh
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    wmesh = factor_mesh(mesh, 1)  # single-device run; W below is logical
    out = {}
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 2, 32), 0,
                                     cfg.vocab),
    }
    for mode in ("gauss-seidel", "jacobi"):
        dcfg = DistConfig(
            num_workers=4, mode=mode,
            gadmm=GADMMConfig(rho=0.5, quantize=True,
                              qcfg=QuantizerConfig(bits=8), alpha=0.01),
            local_iters=2, local_lr=2e-3)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0),
                           dcfg)
        step = jax.jit(tr.make_train_step())
        losses = []
        for _ in range(steps):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        out[mode] = losses
    return out, steps


def main(quick=False):
    out, steps = run(quick=quick)
    gs, jc = out["gauss-seidel"], out["jacobi"]
    # equal compute: one G-S step ~ two Jacobi steps of per-device work
    print(f"jacobi_vs_gs_equal_steps,0,gs={gs[-1]:.4f};jacobi={jc[-1]:.4f}")
    half = len(gs) // 2
    print(f"jacobi_vs_gs_equal_compute,0,"
          f"gs_{half}steps={gs[half-1]:.4f};jacobi_{len(jc)}steps={jc[-1]:.4f}")


if __name__ == "__main__":
    main()
