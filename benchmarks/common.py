"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gadmm
from repro.core.baselines import PSProblem, run_adiana, run_gd
from repro.core.quantizer import QuantizerConfig
from repro.core.topology import random_placement
from repro.core import comm_model as cm
from repro.data.synthetic import regression_shards


def linreg_problem(n_workers=50, samples=20000, d=6, seed=0,
                   heterogeneous=False):
    """Paper Sec. V-A setting: samples distributed uniformly (iid) across
    workers.  f64 when x64 is enabled (needed for loss floors < 1e-6 rel)."""
    xs, ys, _ = regression_shards(n_workers, samples, d, seed,
                                  heterogeneous=heterogeneous)
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    xs, ys = jnp.asarray(xs, dtype), jnp.asarray(ys, dtype)
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    theta_star = jnp.linalg.solve(xtx.sum(0), xty.sum(0))
    return xs, ys, xtx, xty, theta_star


def run_gadmm_curve(xs, ys, cfg: gadmm.GADMMConfig, iters: int, theta_star):
    """Returns losses |F - F*| per iteration."""
    n, _, d = xs.shape
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    fstar = float(q.objective(jnp.broadcast_to(theta_star, (n, d))))
    st = gadmm.init_state(n, d, cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
    losses = []
    for _ in range(iters):
        st = step(st)
        losses.append(abs(float(q.objective(st.theta)) - fstar))
    return np.asarray(losses), st


def rounds_to(losses: np.ndarray, target: float) -> float:
    """First 1-based round with loss <= target; misses are inf (so derived
    totals like rounds * energy flow through as inf without sentinel
    checks — aggregate with np.isfinite)."""
    hit = np.nonzero(losses <= target)[0]
    return float(hit[0]) + 1.0 if len(hit) else float("inf")


def energy_curves(placement, radio: cm.RadioConfig, d: int, iters: int,
                  algs: dict) -> dict:
    """algs: name -> dict(decentralized: bool, bits_per_worker: fn(iter)->bits
    upload, download_bits).  Returns name -> cumulative energy array."""
    out = {}
    bd = placement.broadcast_dist()  # worker-id order (topology-dispatched)
    for name, a in algs.items():
        per_round = []
        if a["decentralized"]:
            e = cm.round_energy_decentralized(
                np.full(placement.n, a["upload_bits"]), bd, radio)
        else:
            e = cm.round_energy_ps(a["upload_bits"], placement.ps_dist,
                                   a["download_bits"], radio)
        out[name] = np.cumsum(np.full(iters, e))
    return out


def timed(fn, *args, reps=5):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(
        r, jax.Array) else None
    return (time.perf_counter() - t0) / reps * 1e6  # us
