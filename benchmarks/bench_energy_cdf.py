"""Paper Fig. 3: CDF of total consumed energy to reach the loss target over
repeated random worker drops, for bandwidths {10, 2, 1} MHz — plus the
event-driven counterpart: the same energy/time-to-target quantities
*measured* by repro.sim playing Q-GADMM out message-by-message (latency,
loss + retransmit, stragglers, async staleness), recorded next to the
closed-form numbers in BENCH_sim.json (``main_sim`` / ``benchmarks.run
--only sim``)."""
from __future__ import annotations

import dataclasses
import json

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import comm_model as cm  # noqa: E402
from repro.core import gadmm  # noqa: E402
from repro.core.baselines import PSProblem, run_adiana, run_gd  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402
from repro.core.topology import build_topology, random_placement  # noqa: E402

from .bench_linreg import REL_TARGET  # noqa: E402
from .common import linreg_problem, rounds_to, run_gadmm_curve  # noqa: E402


def one_experiment(seed: int, n_workers=50, iters=400, rho=24.0, bits=2):
    xs, ys, xtx, xty, theta_star = linreg_problem(n_workers=n_workers,
                                                  seed=seed)
    d = xs.shape[-1]
    prob = PSProblem(xtx=xtx, xty=xty)
    fstar_signed = float(prob.objective(theta_star))
    fstar = abs(fstar_signed)
    target = REL_TARGET * fstar

    def ps_losses(thetas):
        f = jax.vmap(prob.objective)(thetas)
        return np.abs(np.asarray(f) - fstar_signed)

    rounds = {}
    cfg_g = gadmm.GADMMConfig(rho=rho, quantize=False)
    rounds["GADMM"] = rounds_to(run_gadmm_curve(xs, ys, cfg_g, iters,
                                                theta_star)[0], target)
    cfg_q = gadmm.GADMMConfig(rho=rho, quantize=True,
                              qcfg=QuantizerConfig(bits=bits))
    rounds["Q-GADMM"] = rounds_to(run_gadmm_curve(xs, ys, cfg_q, iters,
                                                  theta_star)[0], target)
    thetas, _ = run_gd(prob, iters)
    rounds["GD"] = rounds_to(ps_losses(thetas), target)
    thetas, _ = run_gd(prob, iters, quantize_bits=bits)
    rounds["QGD"] = rounds_to(ps_losses(thetas), target)
    ys_ad, _ = run_adiana(prob, iters, bits=bits)
    rounds["ADIANA"] = rounds_to(ps_losses(ys_ad), target)

    placement = random_placement(n_workers, seed=seed + 1000)
    bd = placement.broadcast_dist()
    out = {}
    for bw in (10e6, 2e6, 1e6):
        radio = cm.RadioConfig(total_bandwidth_hz=bw, n_workers=n_workers)
        for name, r in rounds.items():
            if "GADMM" in name:
                pw = (bits * d + 32) if name.startswith("Q-") else 32 * d
                e = cm.round_energy_decentralized(np.full(n_workers, pw), bd,
                                                  radio)
            else:
                if name == "GD":
                    up = 32 * d
                elif name == "QGD":
                    up = bits * d + 32
                else:
                    up = 32 + 2 * bits * d
                e = cm.round_energy_ps(up, placement.ps_dist, 32 * d, radio)
            out[(name, bw)] = r * e  # rounds_to miss (inf) flows through
    return out


def run(n_exp=20, quick=False):
    if quick:
        n_exp = 5
    rows = [one_experiment(seed) for seed in range(n_exp)]
    algs = ["GADMM", "Q-GADMM", "GD", "QGD", "ADIANA"]
    summary = []
    for bw in (10e6, 2e6, 1e6):
        for alg in algs:
            vals = np.asarray([r[(alg, bw)] for r in rows])
            finite = vals[np.isfinite(vals)]
            med = float(np.median(finite)) if len(finite) else float("inf")
            p90 = float(np.percentile(finite, 90)) if len(finite) else float("inf")
            summary.append(dict(alg=alg, bw=bw, median_J=med, p90_J=p90,
                                success=len(finite) / len(vals)))
    return summary


def main(quick=False):
    for s in run(quick=quick):
        print(f"fig3_energy_cdf_{s['alg']}_{s['bw']/1e6:g}MHz,0,"
              f"median_J={s['median_J']:.3g};p90_J={s['p90_J']:.3g};"
              f"success={s['success']:.2f}")


# ===== simulator-measured curves (repro.sim) ================================
#
# The closed forms above assume lockstep rounds and price the network after
# the fact.  The records below come from the discrete-event runtime: the
# same Q-GADMM math, but every payload traverses a modeled channel.  Under
# an ideal network the measured energy reproduces round_energy_topology
# exactly (asserted in tests/test_sim.py); with loss/stragglers the
# barriered schedule keeps the per-round states bit-identical, so the runs
# converge to the SAME objective while time/energy-to-target move — the
# quantity the paper's headline figures are actually about.

SIM_N = 8
SIM_D = 6
SIM_ROUNDS = 120
SIM_BITS = 2
SIM_RHO = 24.0


def _sim_problem(seed=0):
    from repro.data.synthetic import regression_shards
    import jax.numpy as jnp

    xs, ys, _ = regression_shards(n_workers=SIM_N, samples=2000, d=SIM_D,
                                  seed=seed)
    return jnp.asarray(xs, jnp.float64), jnp.asarray(ys, jnp.float64)


def _sim_scenarios():
    base = []
    for topology in ("chain", "ring", "star"):
        for bw in (10e6, 2e6, 1e6):
            for loss in (0.0, 0.05):
                base.append(dict(topology=topology, bw_hz=bw, loss=loss))
    base.append(dict(topology="chain", bw_hz=2e6, loss=0.0,
                     straggler={1: 10.0}, tag="straggler"))
    # the async dual integrates the round-(k-S) residual every round
    # (sim.worker); the undamped update diverges at this rho, so the
    # scenario carries the paper's damped alpha (same value the async
    # convergence test in tests/test_sim.py pins)
    base.append(dict(topology="ring", bw_hz=2e6, loss=0.0,
                     straggler={3: 8.0}, staleness=2, alpha=0.25,
                     tag="async"))
    base.append(dict(topology="star", bw_hz=2e6, loss=0.0,
                     transport="unicast", tag="hub_serialization"))
    return base


def run_sim(quick=False, seed=0):
    """Simulator-measured scenario matrix (the ``scenarios`` section of
    BENCH_sim.json).

    quick=True (the CI smoke path of ``benchmarks.run``) runs a 3-scenario
    chain subset at half the rounds and does NOT touch the committed
    BENCH_sim.json — only the full run records the artifact the
    tests/test_sim.py artifact check validates."""
    from repro.sim import ComputeModel, NetworkConfig, SimConfig, simulate
    from repro.sim.runner import grid_placement

    xs, ys = _sim_problem(seed)
    cfg = gadmm.GADMMConfig(rho=SIM_RHO, quantize=True,
                            qcfg=QuantizerConfig(bits=SIM_BITS))
    payload_bits = gadmm._payload_bits_per_worker(cfg, SIM_D)
    scenarios = _sim_scenarios()
    rounds = SIM_ROUNDS
    if quick:
        scenarios = [sc for sc in scenarios
                     if sc["topology"] == "chain" and sc["bw_hz"] == 2e6]
        rounds = SIM_ROUNDS // 2
    records = []
    for sc in scenarios:
        topo = build_topology(sc["topology"], SIM_N)
        placement = grid_placement(SIM_N, seed, topo)
        radio = cm.RadioConfig(total_bandwidth_hz=sc["bw_hz"],
                               n_workers=SIM_N)
        scfg = SimConfig(
            topology=sc["topology"], rounds=rounds, seed=seed,
            staleness=sc.get("staleness", 0), radio=radio,
            network=NetworkConfig(loss_prob=sc["loss"],
                                  transport=sc.get("transport",
                                                   "broadcast")),
            compute=ComputeModel(base_s=1e-3,
                                 straggler=sc.get("straggler", {})))
        sc_cfg = dataclasses.replace(cfg, alpha=sc["alpha"]) \
            if "alpha" in sc else cfg
        res = simulate(xs, ys, sc_cfg, scfg, placement=placement)
        tt = res.to_rel_target(REL_TARGET)
        closed_round_j = cm.round_energy_topology(placement, payload_bits,
                                                  radio)
        airtime = np.zeros(SIM_N)
        for r in res.timeline.tx:
            airtime[r.src] += r.airtime_s
        hub = int(np.flatnonzero(topo.head_mask)[0]) \
            if sc["topology"] == "star" else -1
        rec = dict(
            topology=sc["topology"], bw_hz=sc["bw_hz"], loss=sc["loss"],
            straggler=sc.get("straggler", {}),
            staleness=sc.get("staleness", 0),
            transport=sc.get("transport", "broadcast"),
            tag=sc.get("tag", "matrix"),
            rounds_to_target=tt["round"],
            time_to_target_s=tt["time_s"],
            energy_to_target_j=tt["energy_j"],
            closed_form_energy_to_target_j=closed_round_j * tt["round"],
            final_rel_gap=res.final_rel_gap(),
            total_bits=res.timeline.total_bits(),
            retransmissions=res.timeline.retransmissions(),
            makespan_s=res.timeline.makespan_s(),
            events=res.events,
        )
        if hub >= 0:
            leaves = [w for w in range(SIM_N) if w != hub]
            rec["hub_airtime_s"] = float(airtime[hub])
            rec["leaf_airtime_mean_s"] = float(airtime[leaves].mean())
        records.append(rec)
    return records


# ===== massive-N scale section (sim.vectorized) =============================
#
# The event loop above prices an 8-worker matrix; the rows below are the
# tentpole deliverable of the massive-N runtime: a 10^4-worker hierarchical
# cluster-of-stars with 50% per-round participation and 5% packet loss,
# played out by SimConfig.engine='vectorized' (states bit-identical to the
# event loop — locked by tests/test_sim.py — with the whole run finishing
# in seconds of bench wall-clock).  Bandwidth scales with N so the
# per-worker rate matches the 50-worker paper setup.

SCALE_N = 10_000
SCALE_D = 6
SCALE_ROUNDS = 200
SCALE_REL_TARGET = 1e-3


def _scale_scenarios():
    base = dict(topology="cluster_of_stars", loss=0.05, participation=0.5)
    return [base,
            dict(base, participation=1.0, tag="full_participation")]


def run_sim_scale(quick=False, seed=0):
    """Vectorized massive-N rows (the ``scale`` section of BENCH_sim.json).

    quick=True keeps N=10^4 but cuts the rounds — the CI smoke gate runs
    it under a wall-clock cap to pin the 'N=10^4 in seconds' property
    without recording the artifact."""
    import time

    import jax.numpy as jnp

    from repro.data.synthetic import regression_shards
    from repro.sim import NetworkConfig, SimConfig, simulate

    n = SCALE_N
    rounds = SCALE_ROUNDS // 5 if quick else SCALE_ROUNDS
    xs, ys, _ = regression_shards(n_workers=n, samples=4 * n, d=SCALE_D,
                                  seed=seed)
    xs = jnp.asarray(xs, jnp.float64)
    ys = jnp.asarray(ys, jnp.float64)
    cfg = gadmm.GADMMConfig(rho=SIM_RHO, quantize=True,
                            qcfg=QuantizerConfig(bits=SIM_BITS))
    records = []
    scenarios = _scale_scenarios()
    if quick:
        scenarios = scenarios[:1]
    for sc in scenarios:
        scfg = SimConfig(
            topology=sc["topology"], rounds=rounds, seed=seed,
            participation=sc["participation"], engine="vectorized",
            record_states=False,
            radio=cm.RadioConfig(total_bandwidth_hz=2e6 * n / 50.0,
                                 n_workers=n),
            network=NetworkConfig(loss_prob=sc["loss"], latency_s=1e-3))
        t0 = time.time()
        res = simulate(xs, ys, cfg, scfg)
        wall = time.time() - t0
        tt = res.to_rel_target(SCALE_REL_TARGET)
        records.append(dict(
            tag=sc.get("tag", "scale"), engine="vectorized",
            topology=sc["topology"], workers=n, rounds=rounds,
            participation=sc["participation"], loss=sc["loss"],
            rel_target=SCALE_REL_TARGET,
            rounds_to_target=tt["round"],
            time_to_target_s=tt["time_s"],
            energy_to_target_j=tt["energy_j"],
            final_rel_gap=res.final_rel_gap(),
            total_bits=res.timeline.total_bits(),
            retransmissions=res.timeline.retransmissions(),
            makespan_s=res.timeline.makespan_s(),
            bench_wall_s=wall,
        ))
    return records


def main_sim(quick=False):
    scenarios = run_sim(quick=quick)
    for r in scenarios:
        name = (f"sim_{r['topology']}_{r['bw_hz']/1e6:g}MHz_"
                f"loss{r['loss']:g}" + (f"_{r['tag']}"
                                        if r["tag"] != "matrix" else ""))
        print(f"{name},0,rounds={r['rounds_to_target']:g};"
              f"t={r['time_to_target_s']:.3g}s;"
              f"J={r['energy_to_target_j']:.3g};"
              f"gap={r['final_rel_gap']:.2e};"
              f"retx={r['retransmissions']}")
    scale = run_sim_scale(quick=quick)
    for r in scale:
        print(f"sim_scale_{r['topology']}_N{r['workers']}_"
              f"p{r['participation']:g},0,"
              f"rounds={r['rounds_to_target']:g};"
              f"t={r['time_to_target_s']:.3g}s;"
              f"J={r['energy_to_target_j']:.3g};"
              f"gap={r['final_rel_gap']:.2e};"
              f"wall={r['bench_wall_s']:.1f}s")
    if not quick:
        # schema-validated write: obs.record pins the committed artifact's
        # shape (exactly the scenarios + scale sections CI gates on)
        from repro.obs.record import write_bench
        write_bench("BENCH_sim.json",
                    {"scenarios": scenarios, "scale": scale}, "sim")
    print("bench_sim_json,0," + ("quick smoke (artifact untouched)"
                                 if quick else "wrote BENCH_sim.json"))


if __name__ == "__main__":
    main()
