"""Paper Fig. 3: CDF of total consumed energy to reach the loss target over
repeated random worker drops, for bandwidths {10, 2, 1} MHz."""
from __future__ import annotations

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import comm_model as cm  # noqa: E402
from repro.core import gadmm  # noqa: E402
from repro.core.baselines import PSProblem, run_adiana, run_gd  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402
from repro.core.topology import random_placement  # noqa: E402

from .bench_linreg import REL_TARGET  # noqa: E402
from .common import linreg_problem, rounds_to, run_gadmm_curve  # noqa: E402


def one_experiment(seed: int, n_workers=50, iters=400, rho=24.0, bits=2):
    import jax.numpy as jnp

    xs, ys, xtx, xty, theta_star = linreg_problem(n_workers=n_workers,
                                                  seed=seed)
    d = xs.shape[-1]
    prob = PSProblem(xtx=xtx, xty=xty)
    fstar = abs(float(prob.objective(theta_star)))
    target = REL_TARGET * fstar

    def ps_losses(thetas):
        f = jax.vmap(prob.objective)(thetas)
        return np.abs(np.asarray(f) - (-fstar if False else float(
            prob.objective(theta_star))))

    rounds = {}
    cfg_g = gadmm.GADMMConfig(rho=rho, quantize=False)
    rounds["GADMM"] = rounds_to(run_gadmm_curve(xs, ys, cfg_g, iters,
                                                theta_star)[0], target)
    cfg_q = gadmm.GADMMConfig(rho=rho, quantize=True,
                              qcfg=QuantizerConfig(bits=bits))
    rounds["Q-GADMM"] = rounds_to(run_gadmm_curve(xs, ys, cfg_q, iters,
                                                  theta_star)[0], target)
    thetas, _ = run_gd(prob, iters)
    rounds["GD"] = rounds_to(ps_losses(thetas), target)
    thetas, _ = run_gd(prob, iters, quantize_bits=bits)
    rounds["QGD"] = rounds_to(ps_losses(thetas), target)
    ys_ad, _ = run_adiana(prob, iters, bits=bits)
    rounds["ADIANA"] = rounds_to(ps_losses(ys_ad), target)

    placement = random_placement(n_workers, seed=seed + 1000)
    bd = placement.broadcast_dist()
    out = {}
    for bw in (10e6, 2e6, 1e6):
        radio = cm.RadioConfig(total_bandwidth_hz=bw, n_workers=n_workers)
        for name, r in rounds.items():
            if r < 0:
                out[(name, bw)] = np.inf
                continue
            if "GADMM" in name:
                pw = (bits * d + 32) if name.startswith("Q-") else 32 * d
                e = cm.round_energy_decentralized(np.full(n_workers, pw), bd,
                                                  radio)
            else:
                if name == "GD":
                    up = 32 * d
                elif name == "QGD":
                    up = bits * d + 32
                else:
                    up = 32 + 2 * bits * d
                e = cm.round_energy_ps(up, placement.ps_dist, 32 * d, radio)
            out[(name, bw)] = r * e
    return out


def run(n_exp=20, quick=False):
    if quick:
        n_exp = 5
    rows = [one_experiment(seed) for seed in range(n_exp)]
    algs = ["GADMM", "Q-GADMM", "GD", "QGD", "ADIANA"]
    summary = []
    for bw in (10e6, 2e6, 1e6):
        for alg in algs:
            vals = np.asarray([r[(alg, bw)] for r in rows])
            finite = vals[np.isfinite(vals)]
            med = float(np.median(finite)) if len(finite) else float("inf")
            p90 = float(np.percentile(finite, 90)) if len(finite) else float("inf")
            summary.append(dict(alg=alg, bw=bw, median_J=med, p90_J=p90,
                                success=len(finite) / len(vals)))
    return summary


def main(quick=False):
    for s in run(quick=quick):
        print(f"fig3_energy_cdf_{s['alg']}_{s['bw']/1e6:g}MHz,0,"
              f"median_J={s['median_J']:.3g};p90_J={s['p90_J']:.3g};"
              f"success={s['success']:.2f}")


if __name__ == "__main__":
    main()
