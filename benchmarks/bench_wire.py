"""Wire-path benchmark: jnp vs fused Pallas codec through a full train step,
reported-vs-actual wire traffic, and censored-transmission savings
(skip rate + total bits vs the uncensored baseline, per topology).

Times QGADMMTrainer's unsharded reference step (identical codec math to the
sharded step; nibble packing itself runs only inside the sharded exchange's
shard_map, so pack_wire rows here measure the codec + accounting, not the
packing op) for every wire_impl, with and without nibble packing, and
cross-checks `wire_bits_per_round` against the bytes the sharded exchange
actually moves.  Results go to BENCH_wire.json (and the usual
``name,us_per_call,derived`` CSV on stdout).

On this CPU container the 'pallas' numbers are interpret-mode (correctness
harness, expected slower); the structural win of the fused path — one
quantize->pack pipeline over the flat (W, D) buffer instead of L per-leaf
ops — shows up in the jnp-vs-seed-style per-leaf accounting and on real TPU
backends ('pallas_compiled').
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.censor import CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import LayerwiseConfig, QuantizerConfig
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state


class _BenchModel:
    """A few mixed-size leaves; D is dominated by 'emb' so packing wins."""

    @staticmethod
    def init(key, cfg):
        d = cfg["d"]
        k1, k2, k3 = jax.random.split(key, 3)
        return {"emb": jax.random.normal(k1, (d, 16), jnp.float32),
                "w1": jax.random.normal(k2, (16, 16), jnp.float32),
                "b1": jax.random.normal(k3, (16,), jnp.float32)}

    @staticmethod
    def loss_fn(params, batch, cfg):
        h = jnp.tanh(batch["x"] @ params["emb"])
        h = h @ params["w1"] + params["b1"]
        return jnp.mean((h.sum(-1) - batch["y"]) ** 2)


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(d=4096, w=4, quick=False):
    if quick:
        d = 512
    cfg = {"d": d}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (w, 8, d)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (w, 8))}
    rows = []
    records = []
    for wire_impl in ("jnp", "pallas"):
        for pack in (False, True):
            dcfg = DistConfig(
                num_workers=w,
                gadmm=GADMMConfig(rho=0.5, quantize=True,
                                  qcfg=QuantizerConfig(bits=4), alpha=0.01),
                local_iters=1, local_lr=1e-3,
                pack_wire=pack, wire_impl=wire_impl)
            tr = QGADMMTrainer(_BenchModel, cfg, dcfg, mesh)
            state = init_state(lambda k: _BenchModel.init(k, cfg),
                               jax.random.PRNGKey(0), dcfg)
            step = jax.jit(tr.make_train_step())
            us = _timeit(lambda: step(state, batch)[0])
            n_params = sum(int(np.prod(l.shape[1:]))
                           for l in jax.tree.leaves(state.theta))
            reported_bits = tr.wire_bits_per_round(state.theta)
            wire = tr._finish_wire(jnp.zeros((w, n_params), jnp.uint8))
            if pack:  # per-shard nibble packing inside the exchange
                from repro.kernels.pack import ops as pack_ops

                g = tr._group_size()
                shard = wire[0].reshape(g, -1)[0]
                actual_row_bytes = g * pack_ops.pack4(shard, impl="ref").size
            else:
                actual_row_bytes = wire.shape[1] * wire.dtype.itemsize
            assert tr.wire_row_bytes(n_params) == actual_row_bytes
            name = f"wire_step_{wire_impl}{'_packed' if pack else ''}"
            derived = (f"d={n_params};reported_bits={reported_bits};"
                       f"row_bytes={actual_row_bytes}")
            rows.append((name, us, derived))
            # independent actual: measured row bytes + R/b sideband, per
            # link, direction, and phase (2 phases in gauss-seidel)
            sideband = 32 + 32
            actual_bits = 2 * 2 * (w - 1) * (8 * actual_row_bytes + sideband)
            records.append(dict(
                impl=wire_impl, pack_wire=pack, num_workers=w, d=n_params,
                step_us=us, reported_wire_bits_per_round=reported_bits,
                actual_row_bytes=actual_row_bytes,
                actual_bits_per_round=actual_bits))
    # --- censored transmissions: skip-rate + bytes vs the uncensored run ---
    # Run a short training trajectory per topology and accumulate the
    # data-dependent wire_bits_per_round metric; the baseline column is the
    # same trainer with censor=None (static accounting).
    steps = 8 if quick else 24
    for topology in ("chain", "ring"):
        dcfg_kw = dict(
            num_workers=w,
            gadmm=GADMMConfig(rho=0.5, quantize=True,
                              qcfg=QuantizerConfig(bits=4), alpha=0.01),
            local_iters=1, local_lr=1e-3, topology=topology)
        base_tr = QGADMMTrainer(_BenchModel, cfg,
                                DistConfig(**dcfg_kw), mesh)
        cen_tr = QGADMMTrainer(
            _BenchModel, cfg,
            DistConfig(censor=CensorConfig(tau=1.0, xi=0.9), **dcfg_kw),
            mesh)
        state_c = init_state(lambda k: _BenchModel.init(k, cfg),
                             jax.random.PRNGKey(0), cen_tr.dcfg)
        step_c = jax.jit(cen_tr.make_train_step())
        cen_bits = 0.0
        skip = 0.0
        for _ in range(steps):
            state_c, m_c = step_c(state_c, batch)
            cen_bits += float(m_c["wire_bits_per_round"])
            skip += float(m_c["skip_rate"])
        skip /= steps
        # the uncensored baseline accounting is static — no run needed
        base_bits = float(
            steps * base_tr.wire_bits_per_round(state_c.theta))
        name = f"wire_censor_{topology}"
        rows.append((name, 0,
                     f"steps={steps};skip_rate={skip:.3f};"
                     f"bits={cen_bits:.0f}/{base_bits:.0f}"
                     f"={cen_bits / base_bits:.3f}"))
        records.append(dict(
            impl="jnp", topology=topology, censored=True, num_workers=w,
            steps=steps, skip_rate_mean=skip,
            censored_bits_total=cen_bits, baseline_bits_total=base_bits,
            bits_ratio=cen_bits / base_bits))
    rows_l, records_l = _run_layouts(quick=quick)
    rows.extend(rows_l)
    records.extend(records_l)
    rows_lw, records_lw = _run_layerwise(quick=quick)
    rows.extend(rows_lw)
    records.extend(records_lw)
    # quick mode stays below the dense-vs-edge wall-clock crossover (see
    # _run_layouts), so only the full run records the committed artifact —
    # CI gates on its state_layout section showing the edge win on star
    if not quick:
        # schema-validated write: obs.record pins the committed artifact's
        # shape (a new section must extend validate_bench_wire first)
        from repro.obs.record import write_bench
        write_bench("BENCH_wire.json", records, "wire")
    rows.append(("bench_wire_json", 0,
                 "quick smoke (artifact untouched)" if quick
                 else "wrote BENCH_wire.json"))
    return rows


def _hlo_flops(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def _run_layouts(quick=False):
    """Port-dense vs edge-indexed graph_step state layouts.

    The pre-refactor 'port' layout aggregates neighbor terms through dense
    (N, N) / (N, E) operators — O(N^2 d) + O(N E d) per phase regardless of
    how sparse the graph is.  The 'edge' layout (the default since the
    O(E) refactor) gathers over the 2E directed edges and segment_sums —
    O(E d).  Star is the worst case for the dense form (E = N-1 but the
    operators stay N-dense), torus2d the structured-sparse case (E = 2N).
    Both layouts are bitwise-identical (property-tested in
    tests/test_gadmm.py); this records the step-time and HLO-FLOP cost of
    keeping the dense state around.

    Sizing: the dense operators only lose on the wall clock once N·d (the
    adjacency matmul) outweighs the solve einsum and quantizer that both
    layouts share — on this CPU that crossover is around N=512 at d=64
    (below it the dense matmul hides in the shared work even at 5-10x the
    HLO FLOPs), so the full run sits above it and quick mode only records
    the FLOP ratio.
    """
    import functools

    from repro.core import gadmm as cg
    from repro.core.topology import build_topology

    n_star, n_torus, d = (64, 16, 32) if quick else (512, 256, 64)
    cfg = GADMMConfig(rho=1.0, quantize=True, qcfg=QuantizerConfig(bits=4))
    rows, records = [], []
    for topology, n in (("star", n_star), ("torus2d", n_torus)):
        topo = build_topology(topology, n)
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        xs = jax.random.normal(k1, (n, 8, d))
        ys = jax.random.normal(k2, (n, 8))
        q = cg.make_graph_quadratic(xs, ys, cfg.rho, topo)
        state = cg.graph_init_state(topo, d, cfg)
        flops = {}
        us = {}
        for layout in ("port", "edge"):
            step = jax.jit(functools.partial(cg.graph_step, q=q, cfg=cfg,
                                             topo=topo, layout=layout))
            flops[layout] = _hlo_flops(step.lower(state).compile())
            us[layout] = _timeit(lambda: step(state), reps=20)
            rows.append((f"graph_step_{topology}_{layout}", us[layout],
                         f"n={n};e={topo.num_edges};d={d};"
                         f"hlo_flops={flops[layout]:.3g}"))
        rows.append((f"graph_step_{topology}_edge_win", 0,
                     f"time_x={us['port'] / us['edge']:.2f};"
                     f"flops_x={flops['port'] / flops['edge']:.2f}"))
        records.append(dict(
            section="state_layout", topology=topology, num_workers=n,
            num_edges=int(topo.num_edges), d=d,
            port_step_us=us["port"], edge_step_us=us["edge"],
            port_hlo_flops=flops["port"], edge_hlo_flops=flops["edge"],
            time_speedup_edge=us["port"] / us["edge"],
            flops_ratio_edge=flops["port"] / flops["edge"]))
    return rows, records


def _run_layerwise(quick=False):
    """Layerwise (L-FGADMM) wire-bits-to-accuracy vs the uniform wire.

    bench_dnn row: the DNN model above (dominant 'emb' leaf, as in the
    Fig. 4 MLPs) trained to plateau twice from the same init — once with the
    uniform 4-bit wire, once with the dominant leaf on exchange period 2
    (LayerwiseConfig.large_leaf_period) — recording cumulative wire bits and
    the final objective.  The acceptance contract (gated in CI on the
    committed artifact) is bits_ratio_uniform_over_layerwise >= 1.5 at
    rel_objective_gap <= 1e-3.

    qwen1_5_4b row: the same pair for 2 steps of the reduced qwen1.5-4b
    config — a wire-accounting smoke at transformer scale (no accuracy
    claim at 2 steps; the ratio is what's recorded).
    """
    w = 4
    d = 512 if quick else 4096
    steps = 12 if quick else 40
    cfg = {"d": d}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (w, 8, d)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (w, 8))}
    dcfg_kw = dict(
        num_workers=w,
        gadmm=GADMMConfig(rho=0.5, quantize=True,
                          qcfg=QuantizerConfig(bits=4), alpha=0.01),
        local_iters=2, local_lr=1e-3)
    rows, records = [], []

    def trajectory(dcfg):
        tr = QGADMMTrainer(_BenchModel, cfg, dcfg, mesh)
        state = init_state(lambda k: _BenchModel.init(k, cfg),
                           jax.random.PRNGKey(0), dcfg)
        step = jax.jit(tr.make_train_step())
        bits = 0.0
        m = None
        for _ in range(steps):
            state, m = step(state, batch)
            bits += float(m["wire_bits_per_round"])
        return bits, float(m["loss"])

    # Uniform baseline = LayerwiseConfig() defaults: bitwise the same
    # trajectory as the uniform per_tensor wire (tests/test_layerwise.py)
    # under the same per-leaf protocol accounting, so the ratio isolates
    # the layerwise mechanism (the dominant leaf's exchange period), not a
    # difference in billing models.
    bits_u, loss_u = trajectory(DistConfig(
        layerwise=LayerwiseConfig(), **dcfg_kw))
    bits_l, loss_l = trajectory(DistConfig(
        layerwise=LayerwiseConfig(large_leaf_period=2), **dcfg_kw))
    ratio = bits_u / bits_l
    gap = abs(loss_l - loss_u) / max(abs(loss_u), 1e-12)
    rows.append(("wire_layerwise_bench_dnn", 0,
                 f"steps={steps};bits={bits_l:.3g}/{bits_u:.3g};"
                 f"ratio={ratio:.2f};rel_obj_gap={gap:.2e}"))
    records.append(dict(
        section="layerwise", model="bench_dnn", num_workers=w, d=d,
        steps=steps, uniform_bits_total=bits_u, layerwise_bits_total=bits_l,
        bits_ratio_uniform_over_layerwise=ratio,
        uniform_final_loss=loss_u, layerwise_final_loss=loss_l,
        rel_objective_gap=gap))

    # transformer-scale wire-accounting smoke (reduced qwen1.5-4b, 2 steps)
    from repro.data.pipeline import LMShardLoader
    from repro.models import registry

    qcfg = registry.get_config("qwen1.5-4b", smoke=True)
    qmodel = registry.get_model(qcfg)
    wq = 2
    loader = LMShardLoader(wq, 2, 64, qcfg.vocab)
    qbatch = loader.next_batch()
    qsteps = 1 if quick else 2

    def q_trajectory(dcfg):
        tr = QGADMMTrainer(qmodel, qcfg, dcfg, mesh)
        state = init_state(lambda k: qmodel.init(k, qcfg),
                           jax.random.PRNGKey(0), dcfg)
        step = jax.jit(tr.make_train_step())
        bits = 0.0
        for _ in range(qsteps):
            state, m = step(state, qbatch)
            bits += float(m["wire_bits_per_round"])
        return bits

    qkw = dict(num_workers=wq,
               gadmm=GADMMConfig(rho=1.0, quantize=True,
                                 qcfg=QuantizerConfig(bits=4), alpha=0.01),
               local_iters=1, local_lr=1e-3)
    qb_u = q_trajectory(DistConfig(layerwise=LayerwiseConfig(), **qkw))
    qb_l = q_trajectory(DistConfig(
        layerwise=LayerwiseConfig(large_leaf_period=2,
                                  large_leaf_frac=0.01), **qkw))
    rows.append(("wire_layerwise_qwen1_5_4b", 0,
                 f"steps={qsteps};bits={qb_l:.3g}/{qb_u:.3g};"
                 f"ratio={qb_u / qb_l:.2f}"))
    records.append(dict(
        section="layerwise", model="qwen1_5_4b", num_workers=wq,
        steps=qsteps, uniform_bits_total=qb_u, layerwise_bits_total=qb_l,
        bits_ratio_uniform_over_layerwise=qb_u / qb_l))
    return rows, records


def main(quick=False):
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
