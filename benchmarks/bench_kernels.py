"""Kernel micro-benchmarks: fused Pallas quantize-dequantize vs unfused jnp
reference, and nibble pack.  On this CPU container the Pallas numbers are
interpret-mode (correctness harness); the fusion win is structural (HBM
traffic: 6 passes -> 2 reads + 2 writes) and is evaluated via the roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.pack import ops as pack_ops
from repro.kernels.quantize import ops as q_ops


def _timeit(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(n=1 << 20, quick=False):
    if quick:
        n = 1 << 16
    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (n,))
    hat = jnp.zeros_like(theta)
    r = jnp.max(jnp.abs(theta))
    k = jax.random.PRNGKey(1)

    ref_us = _timeit(lambda: q_ops.quantize_dequantize(theta, hat, k, r, 4,
                                                       impl="ref"))
    q, _ = q_ops.quantize_dequantize(theta, hat, k, r, 4, impl="ref")
    pack_us = _timeit(lambda: pack_ops.pack4(q, impl="ref"))

    # HBM traffic model (bytes moved, fused vs unfused) at f32 params:
    unfused = n * 4 * 6   # theta, hat read; c, p, q, hat_new materialized
    fused = n * (4 + 4 + 4) + n * 1 + n * 4  # 3 reads + q(u8) + hat writes
    return [
        ("kernel_quantize_ref_jnp", ref_us, f"n={n}"),
        ("kernel_pack4_ref", pack_us, f"n={n}"),
        ("kernel_quantize_hbm_model", 0,
         f"unfused_bytes={unfused};fused_bytes={fused};"
         f"traffic_ratio={unfused/fused:.2f}"),
    ]


def main(quick=False):
    for name, us, derived in run(quick=quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
