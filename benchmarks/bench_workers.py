"""Paper Fig. 6(a): scalability — total transmitted bits to reach the target
vs number of workers, Q-GADMM vs GADMM."""
from __future__ import annotations

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import gadmm  # noqa: E402
from repro.core.quantizer import QuantizerConfig  # noqa: E402

from .bench_linreg import REL_TARGET  # noqa: E402
from .common import linreg_problem, rounds_to, run_gadmm_curve  # noqa: E402


def run(worker_counts=(10, 20, 50), iters=400, rho=24.0, bits=4, quick=False):
    if quick:
        worker_counts = (10, 20)
    rows = []
    for n in worker_counts:
        xs, ys, xtx, xty, theta_star = linreg_problem(n_workers=n)
        d = xs.shape[-1]
        import jax.numpy as jnp

        from repro.core.baselines import PSProblem

        prob = PSProblem(xtx=xtx, xty=xty)
        target = REL_TARGET * abs(float(prob.objective(theta_star)))
        for name, cfg in [
            ("GADMM", gadmm.GADMMConfig(rho=rho, quantize=False)),
            (f"Q-GADMM-{bits}b",
             gadmm.GADMMConfig(rho=rho, quantize=True,
                               qcfg=QuantizerConfig(bits=bits))),
        ]:
            losses, _ = run_gadmm_curve(xs, ys, cfg, iters, theta_star)
            r = rounds_to(losses, target)
            bpr = gadmm.bits_per_round(cfg, n, d)
            rows.append(dict(alg=name, n=n, rounds=r,
                             total_bits=r * bpr))  # miss -> inf flows
    return rows


def main(quick=False):
    rows = run(quick=quick)
    for r in rows:
        print(f"fig6_workers_{r['alg']}_N{r['n']},0,"
              f"rounds={r['rounds']};bits={r['total_bits']:.3g}")
    # scalability claim: bits grow ~linearly in N with a stable Q/G ratio
    for n in sorted({r["n"] for r in rows}):
        g = next(r for r in rows if r["n"] == n and r["alg"] == "GADMM")
        q = next(r for r in rows if r["n"] == n and r["alg"] != "GADMM")
        if np.isfinite(q["total_bits"]) and np.isfinite(g["total_bits"]):
            print(f"fig6_ratio_N{n},0,q_over_g="
                  f"{q['total_bits']/g['total_bits']:.3f}")


if __name__ == "__main__":
    main()
