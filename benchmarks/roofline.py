"""Roofline table from dry-run JSON (see repro.launch.dryrun / EXPERIMENTS.md).

  PYTHONPATH=src python -m benchmarks.roofline dryrun_singlepod.json
"""
from __future__ import annotations

import json
import sys

HBM_PER_CHIP = 16e9  # v5e


def rows_from(path: str):
    with open(path) as f:
        data = json.load(f)
    rows = []
    for r in data:
        if "error" in r:
            rows.append(dict(arch=r["arch"], shape=r["shape"], error=r["error"]))
            continue
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
        rows.append(dict(
            arch=r["arch"], shape=r["shape"],
            compute_ms=r["compute_s"] * 1e3,
            memory_ms=r["memory_s"] * 1e3,
            collective_ms=r["collective_s"] * 1e3,
            dominant=r["dominant"].replace("_s", ""),
            useful=r["useful_flops_ratio"],
            hbm_gb=hbm / 1e9,
            fits="Y" if hbm <= HBM_PER_CHIP else "N",
        ))
    return rows


def main(argv=None):
    argv = argv or sys.argv[1:]
    path = argv[0] if argv else "dryrun_singlepod.json"
    rows = rows_from(path)
    hdr = (f"{'arch':28s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dominant':>10s} {'useful':>7s} {'GB/dev':>8s} fits")
    print(hdr)
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:28s} {r['shape']:12s} ERROR {r['error'][:60]}")
            continue
        print(f"{r['arch']:28s} {r['shape']:12s} {r['compute_ms']:8.2f}m "
              f"{r['memory_ms']:8.2f}m {r['collective_ms']:8.2f}m "
              f"{r['dominant']:>10s} {r['useful']:7.3f} {r['hbm_gb']:8.2f} "
              f"{r['fits']}")
        print(f"roofline_{r['arch']}_{r['shape']},0,"
              f"compute_ms={r['compute_ms']:.3f};memory_ms={r['memory_ms']:.3f};"
              f"collective_ms={r['collective_ms']:.3f};dominant={r['dominant']};"
              f"useful={r['useful']:.3f};hbm_gb={r['hbm_gb']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
