"""Paper Fig. 4: DNN image classification — test accuracy vs rounds /
transmitted bits for Q-SGADMM / SGADMM / SGD / QSGD (PS-based)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.gadmm import GADMMConfig, bits_per_round
from repro.core.quantizer import QuantizerConfig
from repro.core.sgadmm import SGADMMConfig, SGADMMTrainer
from repro.data.synthetic import classification_shards
from repro.models import mlp


def _sgd_baseline(xs, ys, x_test, y_test, iters, lr=5e-3, batch=100,
                  quantize_bits=None, seed=0, layers=None):
    """PS-based distributed (Q)SGD on the same shards."""
    n = xs.shape[0]
    params = mlp.init_params(jax.random.PRNGKey(seed), layers=layers)
    from jax.flatten_util import ravel_pytree

    flat0, unravel = ravel_pytree(params)
    grad_fn = jax.jit(jax.grad(
        lambda f, xb, yb: mlp.loss_fn(unravel(f), xb, yb)))
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    flat = flat0
    accs = []
    for it in range(iters):
        sel = rng.integers(0, xs.shape[1], size=(n, batch))
        g = jnp.zeros_like(flat)
        for w in range(n):
            xb = xs[w][sel[w]]
            yb = ys[w][sel[w]]
            gw = grad_fn(flat, xb, yb)
            if quantize_bits is not None:
                key, sub = jax.random.split(key)
                r = jnp.max(jnp.abs(gw))
                lev = 2.0 ** quantize_bits - 1
                step = 2 * jnp.maximum(r, 1e-30) / lev
                c = (gw + r) / step
                low = jnp.floor(c)
                u = jax.random.uniform(sub, gw.shape)
                gw = jnp.where(r > 0,
                               step * jnp.clip(low + (u < (c - low)), 0, lev) - r,
                               gw)
            g = g + gw / n
        flat = flat - lr * g
        accs.append(float(mlp.accuracy(unravel(flat), x_test, y_test)))
    d = flat.size
    up = 32 * d if quantize_bits is None else quantize_bits * d + 32
    return np.asarray(accs), n * up + 32 * d


def run(n_workers=10, iters=40, bits=8, rho=1.0, quick=False,
        dim=64, layers=None, target_acc=0.85):
    if quick:
        n_workers, iters = 6, 25
    layers = layers or [(dim, 48), (48, 10)]
    xs, ys = classification_shards(n_workers=n_workers, samples=600 * n_workers,
                                   dim=dim, seed=0)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    x_test = xs.reshape(-1, dim)
    y_test = ys.reshape(-1)

    rows = []
    rng = np.random.default_rng(0)

    def train_admm(quantize):
        p0 = mlp.init_params(jax.random.PRNGKey(0), layers=layers)
        cfg = SGADMMConfig(
            gadmm=GADMMConfig(rho=rho, quantize=quantize,
                              qcfg=QuantizerConfig(bits=bits), alpha=0.01),
            local_iters=10, local_lr=3e-3, batch_size=100)
        tr = SGADMMTrainer(mlp.loss_fn, p0, n_workers, cfg)
        accs = []
        r = np.random.default_rng(1)
        for _ in range(iters):
            sel = r.integers(0, xs.shape[1], size=(n_workers, 100))
            xb = jnp.take_along_axis(xs, jnp.asarray(sel)[:, :, None], axis=1)
            yb = jnp.take_along_axis(ys, jnp.asarray(sel), axis=1)
            tr.train_step(xb, yb)
            accs.append(float(mlp.accuracy(tr.mean_params(), x_test, y_test)))
        return np.asarray(accs), tr.bits_per_round()

    for name, fn in [
        ("Q-SGADMM", lambda: train_admm(True)),
        ("SGADMM", lambda: train_admm(False)),
        ("SGD", lambda: _sgd_baseline(xs, ys, x_test, y_test, iters,
                                      layers=layers)),
        ("QSGD", lambda: _sgd_baseline(xs, ys, x_test, y_test, iters,
                                       quantize_bits=bits, layers=layers)),
    ]:
        accs, bpr = fn()
        hit = np.nonzero(accs >= target_acc)[0]
        r = float(hit[0]) + 1.0 if len(hit) else float("inf")
        rows.append(dict(alg=name, final_acc=float(accs[-1]),
                         rounds_to_target=r,
                         bits_to_target=r * bpr,   # miss -> inf flows
                         bits_per_round=bpr))
    return rows


def main(quick=False):
    rows = run(quick=quick)
    for r in rows:
        print(f"fig4_dnn_{r['alg']},0,final_acc={r['final_acc']:.3f};"
              f"rounds={r['rounds_to_target']};"
              f"bits={r['bits_to_target']:.3g}")


if __name__ == "__main__":
    main()
