"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Full runs:

  PYTHONPATH=src python -m benchmarks.run          # quick mode (CI)
  PYTHONPATH=src python -m benchmarks.run --full   # paper-scale settings
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args = ap.parse_args(argv)
    quick = not args.full

    from . import (bench_compute_time, bench_dnn, bench_energy_cdf,
                   bench_jacobi, bench_kernels, bench_linreg, bench_rho,
                   bench_wire, bench_workers)

    benches = {
        "linreg": bench_linreg.main,          # Fig. 2
        "energy_cdf": bench_energy_cdf.main,  # Fig. 3
        "dnn": bench_dnn.main,                # Fig. 4
        "workers": bench_workers.main,        # Fig. 6
        "rho": bench_rho.main,                # Fig. 7
        "compute_time": bench_compute_time.main,  # Fig. 8
        "kernels": bench_kernels.main,
        "wire": bench_wire.main,              # fused wire path (this repo)
        "sim": bench_energy_cdf.main_sim,     # event-driven runtime (repro.sim)
        "jacobi": bench_jacobi.main,          # beyond-paper variant
    }
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn(quick=quick)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc()
    # roofline table (if a dry-run JSON is present)
    import os

    for path in ("dryrun_singlepod.json", "dryrun_multipod.json",
                 "dryrun_singlepod_opt.json", "dryrun_multipod_opt.json"):
        if os.path.exists(path):
            print(f"# --- roofline ({path}) ---", flush=True)
            from . import roofline

            roofline.main([path])
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
