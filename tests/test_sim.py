"""repro.sim test tier: bit-parity with the lockstep references, channel
accounting against the closed forms, async/fault behavior, the event
loop's determinism, and the recorded BENCH_sim.json artifact.

The keystone contract (ISSUE 4): under an ideal network — zero latency,
lossless, homogeneous compute, staleness 0 — the event-driven runtime's
per-round worker states are BIT-IDENTICAL to core.gadmm.graph_step for
every topology with censoring on/off, and to the distributed trainer's
unsharded reference step.  Asserted with array_equal, not allclose.
"""
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import comm_model as cm
from repro.core import gadmm
from repro.core.censor import CensorConfig
from repro.core.quantizer import QuantizerConfig
from repro.core.topology import bipartite_topology, build_topology
from repro.data.synthetic import regression_shards
from repro.sim import (ComputeModel, Engine, FaultPlan, NetworkConfig,
                       SimConfig, SimLivenessError, simulate,
                       simulate_trainer)
from repro.sim.runner import grid_placement

N, D, ROUNDS = 8, 4, 12


@pytest.fixture(scope="module")
def problem():
    xs, ys, _ = regression_shards(n_workers=N, samples=800, d=D, seed=1)
    return jnp.asarray(xs), jnp.asarray(ys)


def _reference(xs, ys, cfg, kind, censor, rounds):
    topo = build_topology(kind, N)
    q = gadmm.make_graph_quadratic(xs, ys, cfg.rho, topo)
    st = gadmm.graph_init_state(topo, D, cfg, seed=0)
    step = jax.jit(functools.partial(gadmm.graph_step, q=q, cfg=cfg,
                                     topo=topo, censor=censor))
    out = []
    for _ in range(rounds):
        st = step(st)
        out.append(st)
    return out


# ------------------------------------------------------------ engine unit --
def test_engine_deterministic_tie_breaking_and_liveness():
    eng = Engine()
    order = []
    for tag in "abc":
        eng.at(1.0, lambda t=tag: order.append(t))
    eng.after(0.5, lambda: order.append("early"))
    eng.run()
    assert order == ["early", "a", "b", "c"]  # ties in insertion order
    assert eng.now == 1.0

    eng2 = Engine()

    def requeue():
        eng2.after(1.0, requeue)  # never quiesces

    eng2.after(0.0, requeue)
    with pytest.raises(SimLivenessError):
        eng2.run(max_events=50)


# ---------------------------------------------------------- bit parity -----
@pytest.mark.parametrize("kind", ["chain", "ring", "star", "torus2d"])
@pytest.mark.parametrize("censored", [False, True])
def test_ideal_network_bitwise_parity_with_graph_step(problem, kind,
                                                      censored):
    """Acceptance: the simulator under an ideal network is bit-identical
    to core.gadmm.graph_step, per round, per worker, for every state
    field, across all topologies with censoring on/off."""
    xs, ys = problem
    censor = CensorConfig(tau=1.0, xi=0.9) if censored else None
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    ref = _reference(xs, ys, cfg, kind, censor, ROUNDS)
    res = simulate(xs, ys, cfg, SimConfig(topology=kind, rounds=ROUNDS,
                                          seed=0), censor=censor)
    assert len(res.states) == ROUNDS
    for k, (r, s) in enumerate(zip(ref, res.states)):
        for name in ("theta", "theta_hat", "lam", "radius", "bits", "sent"):
            assert np.array_equal(np.asarray(getattr(r, name)), s[name]), \
                (kind, censored, k, name)
    if censored:
        # censoring genuinely fires in this configuration
        assert any(not s["sent"].all() for s in res.states)


def test_ideal_network_parity_full_precision_gadmm(problem):
    """quantize=False (plain GADMM / C-GGADMM wire) stays bit-identical
    too — the sim's full-precision transmission path."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=False)
    ref = _reference(xs, ys, cfg, "ring", None, 6)
    res = simulate(xs, ys, cfg, SimConfig(topology="ring", rounds=6, seed=0))
    for r, s in zip(ref, res.states):
        for name in ("theta", "theta_hat", "lam"):
            assert np.array_equal(np.asarray(getattr(r, name)), s[name])


def test_wire_codec_roundtrip_matches_committed_row(problem):
    """The messages bill (qlev, R, b) on the wire while transporting the
    sender-committed row; this pins the two together: reconstructing from
    the wire content reproduces the committed row (the sim does not invent
    information the wire would not carry)."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    topo = build_topology("chain", N)
    q = gadmm.make_graph_quadratic(xs, ys, cfg.rho, topo)
    tc = gadmm.graph_consts(topo)
    st = gadmm.graph_init_state(topo, D, cfg, seed=0)
    key, k_h, _ = jax.random.split(st.key, 3)

    @jax.jit
    def phase_and_roundtrip(theta, hat, lam, radius, bits, key):
        active = tc["head"]
        _, h, r, b, _, qlev = gadmm.graph_phase(
            theta, hat, lam, radius, bits, active, key, q=q, cfg=cfg,
            tc=tc, step=jnp.zeros((), jnp.int32), censor=None)
        recon = gadmm.dequantize_rows(qlev, hat, r, b)
        return h, recon, active

    h, recon, active = phase_and_roundtrip(st.theta, st.theta_hat, st.lam,
                                           st.radius, st.bits, k_h)
    mask = np.asarray(active)
    assert np.array_equal(np.asarray(h)[mask], np.asarray(recon)[mask])


# ------------------------------------------------- trainer-mode parity -----
class _LinReg:
    @staticmethod
    def init(key, cfg):
        return {"w": jnp.zeros((6,)), "b": jnp.zeros(())}

    @staticmethod
    def loss_fn(params, batch, cfg):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)


@pytest.mark.parametrize("topology,censored", [("chain", False),
                                               ("star", True)])
def test_ideal_network_bitwise_parity_with_dist_trainer(topology, censored):
    """Acceptance: the simulator's trainer mode replays QGADMMTrainer's
    unsharded reference step (local Adam + fused wire codec + censoring)
    bit-identically per round and worker."""
    from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state

    w, rounds = 4, 5
    rng = np.random.default_rng(0)
    x = rng.normal(size=(w, 16, 6))
    y = x @ rng.normal(size=6)
    batch = {"x": jnp.asarray(x, jnp.float32),
             "y": jnp.asarray(y, jnp.float32)}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    dcfg = DistConfig(
        num_workers=w, topology=topology,
        censor=CensorConfig(tau=0.3, xi=0.95) if censored else None,
        gadmm=gadmm.GADMMConfig(rho=0.5, quantize=True,
                                qcfg=QuantizerConfig(bits=4), alpha=0.1),
        local_iters=2, local_lr=5e-2)
    tr = QGADMMTrainer(_LinReg, None, dcfg, mesh)
    st0 = init_state(lambda k: _LinReg.init(k, None), jax.random.PRNGKey(0),
                     dcfg)
    step = jax.jit(tr.make_train_step())
    st, ref = st0, []
    for _ in range(rounds):
        st, _ = step(st, batch)
        ref.append(st)
    res = simulate_trainer(tr, st0, batch,
                           SimConfig(topology=topology, rounds=rounds,
                                     seed=0))
    assert len(res.states) == rounds
    row = lambda tree, i: [np.asarray(l[i]) for l in jax.tree.leaves(tree)]
    for k, (r, snaps) in enumerate(zip(ref, res.states)):
        views = tr.port_views(r)  # edge slabs -> per-(worker, color) views
        for i in range(w):
            s = snaps[i]
            checks = [(row(r.theta, i), jax.tree.leaves(s["theta"])),
                      (row(r.theta_hat, i), jax.tree.leaves(s["hat"])),
                      ([np.asarray(r.radius[i])], [s["radius"]]),
                      ([np.asarray(r.bits[i])], [s["bits"]])]
            for c in range(tr.topo.num_ports):
                checks.append((row(views["hat_nbr"][c], i),
                               jax.tree.leaves(s["hat_nbr"][c])))
                checks.append((row(views["lam_nbr"][c], i),
                               jax.tree.leaves(s["lam_nbr"][c])))
            for a, b in checks:
                assert all(np.array_equal(x, y) for x, y in zip(a, b)), \
                    (topology, censored, k, i)


# ----------------------------------------- channel faults & scheduling -----
def test_lossy_straggler_barriered_run_same_states_longer_clock(problem):
    """Acceptance: a lossy + straggling scenario changes time-to-target
    while the barriered schedule keeps every per-round state bit-identical
    (so it trivially converges to the same objective)."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4))
    rounds = 25
    ideal = simulate(xs, ys, cfg, SimConfig(topology="ring", rounds=rounds,
                                            seed=0))
    messy = simulate(xs, ys, cfg, SimConfig(
        topology="ring", rounds=rounds, seed=0,
        network=NetworkConfig(latency_s=2e-3, jitter_s=1e-3, loss_prob=0.2),
        compute=ComputeModel(base_s=1e-3, jitter_sigma=0.3,
                             straggler={3: 8.0})))
    for a, b in zip(ideal.states, messy.states):
        for name in ("theta", "theta_hat", "lam", "radius", "bits", "sent"):
            assert np.array_equal(a[name], b[name]), name
    assert messy.timeline.makespan_s() > 2.0 * ideal.timeline.makespan_s()
    assert messy.timeline.retransmissions() > 0
    assert messy.timeline.total_energy_j() > ideal.timeline.total_energy_j()


def test_async_staleness_converges_and_hides_stragglers(problem):
    """Bounded-staleness mode: fast workers run ahead of an 8x straggler
    (shorter makespan than the barrier) and still converge to the optimum
    within 1e-3 relative objective gap.

    alpha damps the dual (paper eq. 18): the async schedule integrates the
    round-(k-S) residual every round (sim.worker module docstring), and an
    undamped S-delayed dual ascent at this rho sits outside the delayed-
    iteration stability region — alpha=0.25 is stable for both runs."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True, alpha=0.25,
                            qcfg=QuantizerConfig(bits=4))
    rounds = 80
    compute = ComputeModel(base_s=1e-3, jitter_sigma=0.3,
                           straggler={3: 8.0})
    sync = simulate(xs, ys, cfg, SimConfig(topology="ring", rounds=rounds,
                                           seed=0, compute=compute))
    asy = simulate(xs, ys, cfg, SimConfig(topology="ring", rounds=rounds,
                                          seed=0, staleness=2,
                                          compute=compute))
    assert asy.final_rel_gap() < 1e-3, asy.losses[-1]
    assert sync.final_rel_gap() < 1e-3
    assert asy.timeline.makespan_s() < sync.timeline.makespan_s()


def test_ideal_network_energy_matches_closed_form(problem):
    """Broadcast-transport energy reproduces comm_model's
    round_energy_topology exactly, censored and not (per-group bandwidth
    share, farthest-neighbor broadcast distance, FLAG_BITS for skips)."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    topo = build_topology("chain", N)
    pl = grid_placement(N, 0, topo)
    pbits = gadmm._payload_bits_per_worker(cfg, D)
    radio = cm.RadioConfig(n_workers=N)
    res = simulate(xs, ys, cfg, SimConfig(topology="chain", rounds=10,
                                          seed=0, radio=radio),
                   placement=pl)
    closed = 10 * cm.round_energy_topology(pl, pbits, radio)
    np.testing.assert_allclose(res.timeline.total_energy_j(), closed,
                               rtol=1e-12)
    cen = CensorConfig(tau=1.0, xi=0.9)
    resc = simulate(xs, ys, cfg, SimConfig(topology="chain", rounds=10,
                                           seed=0, radio=radio),
                    censor=cen, placement=pl)
    closed_c = sum(cm.round_energy_topology(pl, pbits, radio,
                                            sent=s["sent"])
                   for s in resc.states)
    np.testing.assert_allclose(resc.timeline.total_energy_j(), closed_c,
                               rtol=1e-12)
    assert resc.timeline.total_energy_j() < res.timeline.total_energy_j()


def test_worker_drop_does_not_deadlock(problem):
    """A worker dying mid-run must not stall its neighbors: drop detection
    unblocks them, duals on dead edges freeze, everyone else finishes."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4))
    res = simulate(xs, ys, cfg, SimConfig(
        topology="ring", rounds=30, seed=0,
        network=NetworkConfig(loss_prob=0.1, detection_delay_s=5e-3),
        faults=FaultPlan(drop_round={2: 7})))
    done = res.timeline.rounds_completed()
    assert done[2] == 7
    assert all(done[w] == 30 for w in range(N) if w != 2)
    assert 2 in res.timeline.dropped_at


@pytest.mark.parametrize("staleness", [0, 2])
def test_star_hub_drop_isolates_leaves_without_deadlock(problem, staleness):
    """Degenerate-graph guard: on a star, the hub dying ISOLATES every
    leaf (its only neighbor is gone).  Drop detection must unfreeze them
    — duals on the dead edges freeze, local phases keep running — in both
    the barriered and the async schedule (where the leaves' common-round
    lag histories stop at the hub's last round)."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True, alpha=0.25,
                            qcfg=QuantizerConfig(bits=4))
    topo = build_topology("star", N)
    hub = int(np.flatnonzero(np.asarray(topo.head_mask))[0])
    rounds = 12
    res = simulate(xs, ys, cfg, SimConfig(
        topology="star", rounds=rounds, seed=0, staleness=staleness,
        network=NetworkConfig(latency_s=1e-3, detection_delay_s=1e-3),
        faults=FaultPlan(drop_round={hub: 3})))
    done = res.timeline.rounds_completed()
    assert done[hub] == 3
    assert all(done[w] == rounds for w in range(N) if w != hub)
    assert np.all(np.isfinite(np.asarray(res.losses)))


# ------------------------------------------- vectorized engine parity ------
_STATE_KEYS = ("theta", "theta_hat", "lam", "radius", "bits", "sent")


def _run_both_engines(xs, ys, cfg, censor=None, **scfg_kw):
    ev = simulate(xs, ys, cfg, SimConfig(engine="events", **scfg_kw),
                  censor=censor)
    vec = simulate(xs, ys, cfg, SimConfig(engine="vectorized", **scfg_kw),
                   censor=censor)
    return ev, vec


def _assert_state_parity(ev, vec, ctx):
    assert len(ev.states) == len(vec.states), ctx
    for k, (a, b) in enumerate(zip(ev.states, vec.states)):
        for name in _STATE_KEYS:
            assert np.array_equal(np.asarray(a[name]),
                                  np.asarray(b[name])), (ctx, k, name)


def _assert_timing_parity(ev, vec, ctx):
    # loss-free broadcast scenarios: the vectorized recurrence replays the
    # event loop's wall-clock and Joules EXACTLY, not just in distribution
    np.testing.assert_array_equal(ev.timeline.global_round_times(),
                                  vec.timeline.global_round_times(),
                                  err_msg=str(ctx))
    assert ev.timeline.makespan_s() == vec.timeline.makespan_s(), ctx
    # per-transmission records match; the AGGREGATES are float sums taken
    # in different orders (Python sum vs numpy pairwise), hence allclose
    np.testing.assert_allclose(ev.timeline.total_energy_j(),
                               vec.timeline.total_energy_j(), rtol=1e-12,
                               err_msg=str(ctx))
    np.testing.assert_allclose(ev.timeline.total_bits(),
                               vec.timeline.total_bits(), rtol=1e-12,
                               err_msg=str(ctx))


@pytest.mark.parametrize("kind", ["chain", "ring", "star", "torus2d",
                                  "cluster_of_stars", "federated"])
@pytest.mark.parametrize("censored", [False, True])
def test_vectorized_bitwise_parity_with_events(problem, kind, censored):
    """Acceptance: SimConfig.engine='vectorized' reproduces the event
    loop bit-identically — per-round worker states on every topology
    (hierarchical ones included) with censoring on/off, and the exact
    wall-clock/energy timeline on the loss-free broadcast channel."""
    xs, ys = problem
    censor = CensorConfig(tau=1.0, xi=0.9) if censored else None
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    ev, vec = _run_both_engines(xs, ys, cfg, censor=censor, topology=kind,
                                rounds=ROUNDS, seed=0)
    _assert_state_parity(ev, vec, (kind, censored))
    _assert_timing_parity(ev, vec, (kind, censored))


def test_vectorized_parity_participation_joins_stragglers(problem):
    """Partial participation + a mid-run join + stragglers + latency: the
    two engines still agree bitwise on states AND on the timeline (the
    scenario is loss-free, so timing is exact too)."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4))
    kw = dict(topology="cluster_of_stars", rounds=20, seed=3,
              participation=0.6,
              network=NetworkConfig(latency_s=1e-3),
              compute=ComputeModel(base_s=1e-3, straggler={2: 6.0}),
              faults=FaultPlan(join_round={5: 4}))
    ev, vec = _run_both_engines(xs, ys, cfg, **kw)
    _assert_state_parity(ev, vec, "participation+join")
    _assert_timing_parity(ev, vec, "participation+join")
    # the schedule genuinely removed workers from rounds
    assert any(not s["sent"].all() for s in ev.states)


def test_vectorized_parity_lossy_channel_states_only(problem):
    """Packet loss: retransmissions never change WHICH payloads commit
    (bounded-retransmit broadcast), so states stay bit-identical; the
    channel draws differ between engines, so wall-clock is only
    distribution-equal and is not compared."""
    xs, ys = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    ev, vec = _run_both_engines(
        xs, ys, cfg, topology="ring", rounds=ROUNDS, seed=1,
        network=NetworkConfig(loss_prob=0.2, latency_s=1e-3))
    _assert_state_parity(ev, vec, "lossy")
    assert vec.timeline.retransmissions() > 0


def test_membership_edge_cases_no_deadlock(problem):
    """Dynamic membership on a hierarchical graph: a worker joining
    mid-run and the LAST leaf of a cluster leaving must not stall anyone
    — neighbors advance over scheduled absences and drop detection
    unfreezes the leader."""
    xs, ys = problem
    from repro.core.topology import cluster_of_stars_topology
    topo = cluster_of_stars_topology(7, clusters=3)
    # find a leader whose cluster has exactly one leaf, and that leaf
    deg = np.asarray(topo.degree)
    leaf = next(w for w in range(7)
                if deg[w] == 1 and deg[topo.neighbors(w)[0]] == 2 + 1)
    joiner = next(w for w in range(7) if deg[w] == 1 and w != leaf)
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4))
    rounds = 12
    res = simulate(xs[:7], ys[:7], cfg, SimConfig(
        topology=topo, rounds=rounds, seed=0,
        network=NetworkConfig(latency_s=1e-3, detection_delay_s=1e-3),
        faults=FaultPlan(drop_round={leaf: 6}, join_round={joiner: 3})))
    done = res.timeline.rounds_completed()
    assert done[leaf] == 6
    assert all(done[w] == rounds for w in range(7) if w != leaf)
    assert np.all(np.isfinite(np.asarray(res.losses)))


def test_event_budget_scales_without_false_liveness_trip(problem):
    """Regression for the liveness budget: a larger-N lossy hierarchical
    run with churn completes within SimConfig.event_budget — the budget
    scales with N, E, the retransmit bound, and membership churn instead
    of tripping SimLivenessError on legitimate long schedules."""
    n = 48
    xs, ys, _ = regression_shards(n_workers=n, samples=4 * n, d=3, seed=2)
    cfg = gadmm.GADMMConfig(rho=5.0, quantize=True,
                            qcfg=QuantizerConfig(bits=2))
    scfg = SimConfig(
        topology="cluster_of_stars", rounds=6, seed=2, record_states=False,
        network=NetworkConfig(loss_prob=0.3, latency_s=1e-3, jitter_s=2e-3,
                              detection_delay_s=1e-3),
        faults=FaultPlan(drop_round={7: 3}, join_round={11: 2}))
    res = simulate(jnp.asarray(xs), jnp.asarray(ys), cfg, scfg)
    done = res.timeline.rounds_completed()
    assert done[7] == 3
    assert all(done[w] == 6 for w in range(n) if w != 7)
    from repro.core.topology import build_topology as _bt
    assert res.events <= scfg.event_budget(_bt("cluster_of_stars", n))


# --------------------------------------------------- liveness property -----
# Guarded like the other property suites (hard import under REPRO_CI=1),
# but per-test rather than per-module: the parity/fault/engine tier above
# must run on bare checkouts too.
if os.environ.get("REPRO_CI") == "1":
    import hypothesis  # noqa: F401  CI promises the property suites: hard fail
_HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare checkouts
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def random_scenario(draw):
        n = draw(st.integers(min_value=2, max_value=7))
        # a random tree is always connected + bipartite
        parents = [draw(st.integers(min_value=0, max_value=i - 1))
                   for i in range(1, n)]
        edges = [(p, i) for i, p in enumerate(parents, start=1)]
        censored = draw(st.booleans())
        loss = draw(st.sampled_from([0.0, 0.1, 0.4]))
        staleness = draw(st.integers(min_value=0, max_value=3))
        drops = {}
        if n > 2 and draw(st.booleans()):
            w = draw(st.integers(min_value=0, max_value=n - 1))
            drops[w] = draw(st.integers(min_value=0, max_value=4))
        seed = draw(st.integers(min_value=0, max_value=2**16))
        return n, edges, censored, loss, staleness, drops, seed

    @settings(max_examples=15, deadline=None)
    @given(random_scenario())
    def test_event_loop_never_deadlocks(scenario):
        """Property: random topology x censoring x packet loss x worker
        drops x staleness never deadlocks the scheduler — every live
        worker reaches the round budget within a bounded event count (the
        runner asserts no-deadlock internally; SimLivenessError guards
        livelock)."""
        n, edges, censored, loss, staleness, drops, seed = scenario
        topo = bipartite_topology(n, edges)
        rounds = 6
        xs, ys, _ = regression_shards(n_workers=n, samples=4 * n, d=3,
                                      seed=seed % 7)
        res = simulate(
            jnp.asarray(xs), jnp.asarray(ys),
            gadmm.GADMMConfig(rho=5.0, quantize=True,
                              qcfg=QuantizerConfig(bits=2)),
            SimConfig(topology=topo, rounds=rounds, seed=seed,
                      staleness=staleness, record_states=False,
                      network=NetworkConfig(loss_prob=loss, latency_s=1e-3,
                                            jitter_s=2e-3,
                                            detection_delay_s=1e-3),
                      faults=FaultPlan(drop_round=drops)),
            censor=CensorConfig(tau=1.0, xi=0.9) if censored else None)
        done = res.timeline.rounds_completed()
        for w in range(n):
            if w in drops:
                assert done[w] == min(drops[w], rounds)
            else:
                assert done[w] == rounds
        assert res.events <= SimConfig(topology=topo, rounds=rounds,
                                       seed=seed).event_budget(topo)
    @st.composite
    def random_engine_scenario(draw):
        n = draw(st.integers(min_value=2, max_value=7))
        parents = [draw(st.integers(min_value=0, max_value=i - 1))
                   for i in range(1, n)]
        edges = [(p, i) for i, p in enumerate(parents, start=1)]
        censored = draw(st.booleans())
        loss = draw(st.sampled_from([0.0, 0.3]))
        participation = draw(st.sampled_from([1.0, 0.6]))
        joins = {}
        if n > 2 and draw(st.booleans()):
            w = draw(st.integers(min_value=0, max_value=n - 1))
            joins[w] = draw(st.integers(min_value=1, max_value=3))
        seed = draw(st.integers(min_value=0, max_value=2**16))
        return n, edges, censored, loss, participation, joins, seed

    @settings(max_examples=12, deadline=None)
    @given(random_engine_scenario())
    def test_vectorized_matches_events_property(scenario):
        """Property: over random trees x censoring x loss x partial
        participation x late joins, the vectorized engine's per-round
        states are bit-identical to the event-loop oracle's."""
        n, edges, censored, loss, participation, joins, seed = scenario
        topo = bipartite_topology(n, edges)
        xs, ys, _ = regression_shards(n_workers=n, samples=4 * n, d=3,
                                      seed=seed % 7)
        xs, ys = jnp.asarray(xs), jnp.asarray(ys)
        cfg = gadmm.GADMMConfig(rho=5.0, quantize=True,
                                qcfg=QuantizerConfig(bits=2))
        censor = CensorConfig(tau=1.0, xi=0.9) if censored else None
        kw = dict(topology=topo, rounds=5, seed=seed,
                  participation=participation,
                  network=NetworkConfig(loss_prob=loss, latency_s=1e-3,
                                        detection_delay_s=1e-3),
                  faults=FaultPlan(join_round=joins))
        ev, vec = _run_both_engines(xs, ys, cfg, censor=censor, **kw)
        _assert_state_parity(ev, vec, scenario)
        if loss == 0.0:
            _assert_timing_parity(ev, vec, scenario)
else:  # keep the skip visible in bare-checkout test reports
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_event_loop_never_deadlocks():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_vectorized_matches_events_property():
        pass


# --------------------------------------------------- recorded artifact -----
def test_recorded_bench_sim_artifact():
    """BENCH_sim.json (benchmarks.run --only sim) must hold the full
    scenario matrix with the acceptance-criteria physics: every scenario
    converges (<= 1e-3 relative gap), loss and stragglers stretch
    time-to-target without changing the objective, the ideal-network
    energy matches the closed form, the star-unicast run exposes the
    hub serialization ROADMAP.md quotes, and the ``scale`` section
    records the vectorized 10^4-worker partial-participation run."""
    root = os.path.join(os.path.dirname(__file__), "..")
    path = os.path.join(root, "BENCH_sim.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_sim.json not generated yet")
    doc = json.load(open(path))
    assert set(doc) == {"scenarios", "scale"}, sorted(doc)
    rows = doc["scenarios"]
    matrix = [r for r in rows if r["tag"] == "matrix"]
    assert len(matrix) == 3 * 3 * 2, len(matrix)  # topo x bw x loss
    assert {r["topology"] for r in matrix} == {"chain", "ring", "star"}
    for r in rows:
        assert np.isfinite(r["time_to_target_s"]), r
        assert r["final_rel_gap"] <= 1e-3, r
    by_key = {(r["topology"], r["bw_hz"], r["loss"]): r for r in matrix}
    for topo in ("chain", "ring", "star"):
        for bw in (10e6, 2e6, 1e6):
            clean, lossy = by_key[(topo, bw, 0.0)], by_key[(topo, bw, 0.05)]
            # barriered: same trajectory (same rounds/gap), more wall-clock
            assert lossy["rounds_to_target"] == clean["rounds_to_target"]
            assert lossy["final_rel_gap"] == clean["final_rel_gap"]
            assert lossy["time_to_target_s"] > clean["time_to_target_s"]
            assert lossy["retransmissions"] > 0 == clean["retransmissions"]
            # ideal-network energy == closed form
            np.testing.assert_allclose(
                clean["energy_to_target_j"],
                clean["closed_form_energy_to_target_j"], rtol=1e-9)
    strag = next(r for r in rows if r["tag"] == "straggler")
    base = by_key[(strag["topology"], strag["bw_hz"], 0.0)]
    assert strag["time_to_target_s"] > 2.0 * base["time_to_target_s"]
    assert strag["final_rel_gap"] == base["final_rel_gap"]
    asy = next(r for r in rows if r["tag"] == "async")
    assert asy["staleness"] > 0
    hub = next(r for r in rows if r["tag"] == "hub_serialization")
    assert hub["transport"] == "unicast"
    assert hub["hub_airtime_s"] > 3.0 * hub["leaf_airtime_mean_s"]
    assert (hub["makespan_s"]
            > 1.5 * by_key[("star", hub["bw_hz"], 0.0)]["makespan_s"])
    # scale section: the massive-N deliverable — 10^4 workers, partial
    # participation, lossy channel, vectorized engine, and the whole
    # bench run measured in seconds (not minutes) of wall-clock
    scale = doc["scale"]
    sc = next(r for r in scale if r["tag"] == "scale")
    assert sc["engine"] == "vectorized" and sc["workers"] >= 10_000
    assert sc["topology"] == "cluster_of_stars"
    assert sc["participation"] == 0.5 and sc["loss"] == 0.05
    assert np.isfinite(sc["time_to_target_s"]), sc
    assert np.isfinite(sc["energy_to_target_j"]), sc
    assert sc["final_rel_gap"] <= sc["rel_target"], sc
    assert sc["bench_wall_s"] < 60.0, sc
    full = next(r for r in scale if r["tag"] == "full_participation")
    # half the workers per round -> roughly half the wire traffic
    assert sc["total_bits"] < 0.7 * full["total_bits"], (
        sc["total_bits"], full["total_bits"])
