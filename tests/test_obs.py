"""Observability tier (ISSUE 9): schema round-trips, telemetry parity,
trace accounting, live invariants, and the committed BENCH_* shapes.

The keystone contract: telemetry is a pure READ of the step's state —
running the trainer with telemetry on (metrics appended to a MetricsLog
and drained in windows) yields a state stream BIT-IDENTICAL to telemetry
off.  Asserted with array_equal on every DistState field (bf16 viewed as
uint8), never allclose.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.censor import FLAG_BITS, CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import LayerwiseConfig, QuantizerConfig
from repro.data.synthetic import regression_shards
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
from repro.obs import checks, record, trace
from repro.sim import SimConfig, simulate


# --------------------------------------------------------------- fixtures --
class MixedModel:
    """Mixed-precision pytree (f32 + bf16 + zero-size leaf), same shape as
    the wire-path suite's model so telemetry covers every leaf kind."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wa": jax.random.normal(k1, (6, 4), jnp.float32),
            "wb": (0.1 * jax.random.normal(k2, (4, 3))).astype(jnp.bfloat16),
            "bias": jax.random.normal(k3, (3,), jnp.float32),
            "empty": jnp.zeros((0,), jnp.float32),
        }

    @staticmethod
    def loss_fn(params, batch, cfg):
        h = batch["x"] @ params["wa"]
        h = h @ params["wb"].astype(jnp.float32) + params["bias"]
        return jnp.mean((h.sum(-1) - batch["y"]) ** 2)


def _setup(w=4, **dcfg_kw):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    kw = dict(num_workers=w,
              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                qcfg=QuantizerConfig(bits=4), alpha=0.01),
              local_iters=2, local_lr=1e-2)
    kw.update(dcfg_kw)
    dcfg = DistConfig(**kw)
    tr = QGADMMTrainer(MixedModel, None, dcfg, mesh)
    state = init_state(lambda k: MixedModel.init(k, None),
                       jax.random.PRNGKey(0), dcfg)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (w, 8, 6)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (w, 8))}
    return tr, state, batch


def _assert_states_equal(sa, sb, msg=""):
    for field in sa._fields:
        la = jax.tree.leaves(getattr(sa, field))
        lb = jax.tree.leaves(getattr(sb, field))
        assert len(la) == len(lb)
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8) if a.dtype == jnp.bfloat16
                else np.asarray(a),
                np.asarray(b).view(np.uint8) if b.dtype == jnp.bfloat16
                else np.asarray(b),
                err_msg=f"state field {field} diverged {msg}")


# ----------------------------------------------------- record: schema -------
def test_validate_record_round_trip_every_kind(tmp_path):
    """Every record constructor emits a record that validates and survives
    the JSONL round trip byte-for-byte."""
    recs = [
        record.manifest_record({"rho": 0.5}, seed=3, topology="ring",
                               num_workers=8, extra={"cli": "test"}),
        record.step_record(0, {"loss": np.float32(1.5),
                               "leaf_bits": np.arange(3.0)}, wall_s=0.1),
        record.round_record(2, t_s=1.25, loss=0.7,
                            metrics={"energy_j": 3.0}),
        record.summary_record({"steps": 10, "s_per_step": 0.1}),
        record.bench_record("wire", [{"impl": "jnp", "num_workers": 4}]),
    ]
    path = tmp_path / "run.jsonl"
    with open(path, "w") as f:
        for rec in recs:
            record.validate_record(rec)
            f.write(json.dumps(rec) + "\n")
    loaded = record.validate_run(str(path))
    assert [r["kind"] for r in loaded] == list(record.RECORD_KINDS)
    # numpy values were jsonified at construction time
    assert loaded[1]["metrics"]["loss"] == 1.5
    assert loaded[1]["metrics"]["leaf_bits"] == [0.0, 1.0, 2.0]
    assert loaded[0]["config_hash"] == record.config_hash({"rho": 0.5})


def test_validate_record_rejects_malformed():
    with pytest.raises(ValueError, match="schema"):
        record.validate_record({"kind": "step"})
    with pytest.raises(ValueError, match="kind"):
        record.validate_record({"schema": record.SCHEMA, "kind": "nope"})
    with pytest.raises(ValueError, match="metrics"):
        record.validate_record({"schema": record.SCHEMA, "kind": "step",
                                "step": 0, "metrics": {}})
    with pytest.raises(ValueError, match="topology"):
        record.validate_record({"schema": record.SCHEMA, "kind": "manifest",
                                "config": {}, "topology": None})


def test_validate_run_requires_manifest_first(tmp_path):
    path = tmp_path / "bad.jsonl"
    with open(path, "w") as f:
        f.write(json.dumps(record.step_record(0, {"loss": 1.0})) + "\n")
    with pytest.raises(ValueError, match="manifest"):
        record.validate_run(str(path))


def test_config_hash_stable_and_order_insensitive():
    a = record.config_hash({"b": 2, "a": 1})
    b = record.config_hash({"a": 1, "b": 2})
    assert a == b and len(a) == 12
    assert record.config_hash({"a": 1, "b": 3}) != a


def test_metrics_log_windows_and_file(tmp_path):
    path = tmp_path / "log.jsonl"
    manifest = record.manifest_record({}, seed=0, topology="chain",
                                      num_workers=2)
    with record.MetricsLog(str(path), manifest, log_every=2) as mlog:
        for step in range(5):
            mlog.append(step, {"loss": jnp.float32(step)})
            drained = mlog.maybe_drain(step)
            assert bool(drained) == (step % 2 == 1)
        mlog.close(summary={"steps": 5})
    recs = record.validate_run(str(path))
    kinds = [r["kind"] for r in recs]
    assert kinds == ["manifest"] + ["step"] * 5 + ["summary"]
    assert [r["step"] for r in recs[1:6]] == list(range(5))
    assert all(r["wall_s"] > 0 for r in recs[1:6])


# ------------------------------------------------- trainer: telemetry -------
@pytest.mark.parametrize("variant", ["plain", "censored", "layerwise"])
def test_telemetry_parity_bitwise(variant):
    """Telemetry on == telemetry off, bitwise, for every state field —
    with the on-run's metrics buffered through a draining MetricsLog
    exactly as launch.train wires it."""
    kw = {}
    if variant == "censored":
        kw["censor"] = CensorConfig(tau=0.5, xi=0.95)
    if variant == "layerwise":
        kw["layerwise"] = LayerwiseConfig(bits=(4, 2, 3, 1),
                                          periods=(1, 2, 1, 1), taus=1e-6)
    tr_on, st_on, batch = _setup(telemetry=True, **kw)
    tr_off, st_off, _ = _setup(telemetry=False, **kw)
    step_on = jax.jit(tr_on.make_train_step())
    step_off = jax.jit(tr_off.make_train_step())
    mlog = record.MetricsLog(log_every=2)   # in-memory, drains mid-run
    for k in range(4):
        st_on, m_on = step_on(st_on, batch)
        mlog.append(k, m_on)
        mlog.maybe_drain(k)
        st_off, m_off = step_off(st_off, batch)
        assert "wire_bits_payload" in m_on
        assert "wire_bits_payload" not in m_off
    mlog.close()
    _assert_states_equal(st_on, st_off, f"(telemetry, {variant})")
    steps = [r for r in mlog.records if r["kind"] == "step"]
    assert len(steps) == 4


@pytest.mark.parametrize("variant", ["plain", "censored", "layerwise"])
def test_telemetry_components_sum_and_checks(variant):
    """The split wire accounting reconciles with the billed total, and the
    live invariants accept a healthy run (check_step_window +
    check_edge_mirrors)."""
    kw = {}
    if variant == "censored":
        kw["censor"] = CensorConfig(tau=0.5, xi=0.95)
    if variant == "layerwise":
        kw["layerwise"] = LayerwiseConfig(bits=(4, 2, 3, 1),
                                          periods=(1, 2, 1, 1), taus=1e-6)
    tr, state, batch = _setup(telemetry=True, **kw)
    step = jax.jit(tr.make_train_step())
    mlog = record.MetricsLog(log_every=10)
    for k in range(3):
        state, metrics = step(state, batch)
        mlog.append(k, metrics)
    recs = mlog.drain()
    checks.check_step_window(tr, state, recs)
    checks.check_edge_mirrors(tr, state)
    for rec in recs:
        m = rec["metrics"]
        assert np.isclose(m["wire_bits_payload"] + m["wire_bits_header"]
                          + m["wire_bits_flags"], m["wire_bits_per_round"],
                          rtol=1e-6)
        if variant == "plain":
            assert m["wire_bits_flags"] == 0.0
            assert m["skip_links"] == 0.0
        if variant == "censored":
            assert m["tx_links"] + m["skip_links"] > 0
        if variant == "layerwise":
            assert len(m["leaf_bits"]) == 4   # one entry per pytree leaf
    assert recs[-1]["metrics"]["participants"] == 4.0


def test_check_step_window_catches_corruption():
    tr, state, batch = _setup(telemetry=True)
    step = jax.jit(tr.make_train_step())
    state, metrics = step(state, batch)
    mlog = record.MetricsLog(log_every=10)
    mlog.append(0, metrics)
    recs = mlog.drain()
    recs[0]["metrics"]["wire_bits_payload"] += 64.0
    with pytest.raises(checks.ObsCheckError):
        checks.check_step_window(tr, state, recs)


def test_check_edge_mirrors_catches_desync():
    tr, state, batch = _setup(telemetry=True)
    step = jax.jit(tr.make_train_step())
    state, _ = step(state, batch)
    lam = jax.tree.map(lambda x: np.array(jax.device_get(x)),
                       state.lam_edge)
    leaf = jax.tree.leaves(lam)[0]
    leaf[0] += 10.0                      # break one directed row's mirror
    bad = state._replace(lam_edge=jax.tree.map(jnp.asarray, lam))
    with pytest.raises(checks.ObsCheckError, match="mirror"):
        checks.check_edge_mirrors(tr, bad)


def test_wire_bits_components_match_total_exactly():
    """Static (non-censored) accounting is exact, not just close: the
    component split recomputes the same integers as wire_bits_per_round."""
    tr, state, batch = _setup(telemetry=True)
    total = float(tr.wire_bits_per_round(state.theta))
    pay, hdr, flg = (float(x) for x in tr.wire_bits_components(state.theta))
    assert pay + hdr + flg == total
    assert flg == 0.0


# ------------------------------------------------------- sim: traces --------
@pytest.fixture(scope="module")
def sim_problem():
    xs, ys, _ = regression_shards(n_workers=6, samples=240, d=3, seed=1)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.mark.parametrize("engine", ["events", "vectorized"])
def test_trace_export_valid_and_bits_reconcile(sim_problem, tmp_path,
                                               engine):
    """Perfetto export from both engines: the file loads, per-track X
    timestamps are monotone, and the summed tx bits equal
    Timeline.total_bits() — plus the live timeline/trace invariants."""
    xs, ys = sim_problem
    cfg = GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    res = simulate(xs, ys, cfg,
                   SimConfig(topology="ring", rounds=5, seed=0,
                             engine=engine),
                   censor=CensorConfig(tau=1.0, xi=0.9))
    events = trace.timeline_trace(res.timeline)
    path = tmp_path / f"{engine}.trace.json"
    trace.write_trace(str(path), events)
    evs = trace.load_trace(str(path))   # validates on load
    # per-(pid, tid) monotone timestamps for duration events
    last = {}
    for ev in evs:
        if ev.get("ph") != "X":
            continue
        key = (ev["pid"], ev["tid"])
        assert ev["ts"] >= last.get(key, -1.0), key
        last[key] = ev["ts"]
    assert trace.trace_tx_bits(evs) == res.timeline.total_bits()
    checks.check_timeline(res.timeline)
    checks.check_trace(evs, res.timeline)


def test_trace_truncation_warns_and_stays_valid(sim_problem, capsys):
    xs, ys = sim_problem
    cfg = GADMMConfig(rho=24.0, quantize=False)
    res = simulate(xs, ys, cfg, SimConfig(topology="ring", rounds=4, seed=0))
    events = trace.timeline_trace(res.timeline, max_events=20)
    assert "truncated" in capsys.readouterr().out.lower()
    trace.validate_trace({"traceEvents": events})
    # truncated export bills fewer bits; check_trace skips the reconcile
    assert trace.trace_tx_bits(events) < res.timeline.total_bits()
    checks.check_trace(events, res.timeline)


def test_timeline_dedupe_array_and_list_agree(sim_problem):
    """Timeline and ArrayTimeline answer the shared TimelineBase queries
    identically for the same run (vectorized parity corollary)."""
    xs, ys = sim_problem
    cfg = GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    scfg = dict(topology="ring", rounds=5, seed=0)
    ev = simulate(xs, ys, cfg, SimConfig(engine="events", **scfg))
    vec = simulate(xs, ys, cfg, SimConfig(engine="vectorized", **scfg))
    assert ev.timeline.total_bits() == vec.timeline.total_bits()
    assert np.isclose(ev.timeline.total_energy_j(),
                      vec.timeline.total_energy_j(), rtol=1e-9)
    np.testing.assert_allclose(ev.timeline.per_worker_energy_j(),
                               vec.timeline.per_worker_energy_j(),
                               rtol=1e-9)
    assert ev.timeline.rounds_completed() == vec.timeline.rounds_completed()
    # tx records still reachable as a list on the event engine (legacy API)
    assert sum(t.bits for t in ev.timeline.tx) == ev.timeline.total_bits()
    f = vec.timeline.tx_fields()
    assert set(f) == {"t", "src", "dst", "bits", "energy_j", "airtime_s",
                      "attempt", "rnd"}


# -------------------------------------------------- committed artifacts -----
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_committed_bench_wire_schema():
    with open(os.path.join(ROOT, "BENCH_wire.json")) as f:
        doc = json.load(f)
    record.validate_bench_wire(doc)
    record.validate_record(record.bench_record("wire", doc))


def test_committed_bench_sim_schema():
    with open(os.path.join(ROOT, "BENCH_sim.json")) as f:
        doc = json.load(f)
    record.validate_bench_sim(doc)
    record.validate_record(record.bench_record("sim", doc))


def test_write_bench_rejects_malformed(tmp_path):
    with pytest.raises(ValueError, match="non-empty list"):
        record.write_bench(str(tmp_path / "w.json"), [], "wire")
    with pytest.raises(ValueError, match="sections"):
        record.write_bench(str(tmp_path / "s.json"), {"scenarios": []},
                           "sim")
    assert not (tmp_path / "w.json").exists()


# ------------------------------------------------------- report CLI ---------
def _write_run(path, loss0):
    manifest = record.manifest_record({"rho": 0.5}, seed=0, topology="ring",
                                      num_workers=4)
    with record.MetricsLog(str(path), manifest, log_every=2) as mlog:
        for k in range(6):
            mlog.append(k, {"loss": loss0 / (k + 1),
                            "wire_bits_per_round": 1024.0,
                            "skip_rate": 0.25})
            mlog.maybe_drain(k)
        mlog.close(summary={"steps": 6, "s_per_step": 0.01})


def test_report_cli_single_and_diff(tmp_path, capsys):
    from repro.launch import report
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(a, 2.0)
    _write_run(b, 1.0)
    report.main([str(a), "--target", "0.5"])
    out = capsys.readouterr().out
    assert "loss_last" in out and "wire_bits" in out
    report.main([str(a), str(b)])
    out = capsys.readouterr().out
    assert "B/A" in out
