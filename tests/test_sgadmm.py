"""Q-SGADMM (DNN, stochastic, non-convex) system tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import QuantizerConfig
from repro.core.sgadmm import SGADMMConfig, SGADMMTrainer
from repro.data.synthetic import classification_shards
from repro.models import mlp


def _make_trainer(quantize, bits=8, n=6, seed=0, layers=((32, 24), (24, 10))):
    p0 = mlp.init_params(jax.random.PRNGKey(seed), layers=list(layers))
    cfg = SGADMMConfig(
        gadmm=GADMMConfig(rho=1.0, quantize=quantize,
                          qcfg=QuantizerConfig(bits=bits), alpha=0.01),
        local_iters=10, local_lr=3e-3, batch_size=64)
    return SGADMMTrainer(mlp.loss_fn, p0, n, cfg)


@pytest.fixture(scope="module")
def data():
    n = 6
    xs, ys = classification_shards(n_workers=n, samples=1800, dim=32, seed=0)
    return jnp.asarray(xs), jnp.asarray(ys)


def _train(tr, xs, ys, iters, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(iters):
        sel = rng.integers(0, xs.shape[1], size=(xs.shape[0], 64))
        xb = jnp.take_along_axis(xs, jnp.asarray(sel)[:, :, None], axis=1)
        yb = jnp.take_along_axis(ys, jnp.asarray(sel), axis=1)
        tr.train_step(xb, yb)
    return tr


def test_qsgadmm_reaches_accuracy(data):
    xs, ys = data
    tr = _train(_make_trainer(quantize=True, bits=8), xs, ys, 40)
    x_all, y_all = xs.reshape(-1, xs.shape[-1]), ys.reshape(-1)
    acc = float(mlp.accuracy(tr.mean_params(), x_all, y_all))
    assert acc > 0.8, acc


def test_qsgadmm_matches_sgadmm(data):
    """Paper Fig. 4: quantized and unquantized reach similar accuracy."""
    xs, ys = data
    x_all, y_all = xs.reshape(-1, xs.shape[-1]), ys.reshape(-1)
    tr_q = _train(_make_trainer(quantize=True, bits=8), xs, ys, 40)
    tr_f = _train(_make_trainer(quantize=False), xs, ys, 40)
    acc_q = float(mlp.accuracy(tr_q.mean_params(), x_all, y_all))
    acc_f = float(mlp.accuracy(tr_f.mean_params(), x_all, y_all))
    assert acc_q > acc_f - 0.08, (acc_q, acc_f)
    assert tr_q.bits_per_round() < tr_f.bits_per_round() / 3.5


def test_workers_reach_consensus(data):
    xs, ys = data
    tr = _train(_make_trainer(quantize=True, bits=8), xs, ys, 30)
    theta = tr.state.theta
    spread = float(jnp.max(jnp.abs(theta - jnp.mean(theta, axis=0, keepdims=True))))
    scale = float(jnp.max(jnp.abs(theta)))
    assert spread < 0.35 * scale, (spread, scale)


def test_mlp_paper_architecture_size():
    assert mlp.num_params() == 784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10
