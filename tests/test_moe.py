"""MoE layer invariants: routing, capacity, load-balance aux, expert-parallel
dispatch correctness (hypothesis where useful)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("REPRO_CI") == "1":
    import hypothesis  # noqa: F401  CI promises the property suites: hard fail
else:
    pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import registry
from repro.models.moe import _dispatch_group, moe_apply


def _cfg(**kw):
    return registry.get_config("qwen3-moe-235b-a22b", smoke=True, **kw)


def test_moe_matches_dense_per_token_computation():
    """With drop-free capacity, the MoE output equals explicitly computing
    each token's top-k experts densely."""
    cfg = _cfg()
    m = cfg.moe
    from repro.models.moe import init_moe

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    out, aux = moe_apply(p, x, cfg)

    # dense reference
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, m.top_k)
    gates = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(12):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(m.top_k):
                e = int(top_ids[b, s, j])
                up = x[b, s] @ p["w_up"][e]
                gt = jax.nn.silu(x[b, s] @ p["w_gate"][e]) * up
                acc = acc + gates[b, s, j] * (gt @ p["w_down"][e])
            ref = ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4,
                               rtol=2e-4)
    assert float(aux) >= 0


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_dispatch_group_conservation(seed):
    """Every kept slot lands in exactly one buffer row of its expert, and
    per-expert occupancy never exceeds capacity."""
    key = jax.random.PRNGKey(seed)
    t, k, e, cap, d = 16, 2, 4, 6, 8
    ids = jax.random.randint(key, (t, k), 0, e)
    gates = jnp.ones((t, k)) / k
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d))
    buf, dest, keep, tok, gate = _dispatch_group(x, ids, gates, cap, e)
    # occupancy per expert <= capacity
    counts = np.bincount(np.asarray(dest)[np.asarray(keep)], minlength=e * cap)
    assert (counts <= 1).all()  # each slot distinct
    per_expert = np.asarray(keep).reshape(-1)
    # kept slots reconstruct their token row exactly
    buf_np = np.asarray(buf)
    x_np = np.asarray(x)
    for i in range(t * k):
        if per_expert[i]:
            np.testing.assert_allclose(buf_np[int(dest[i])],
                                       x_np[int(tok[i])], atol=1e-6)


def test_capacity_drops_are_bounded():
    """With capacity_factor < E/k some slots drop, but never more than the
    overflow beyond per-expert capacity."""
    cfg = _cfg()
    t, k, e = 32, 2, 4
    cap = 3  # tight
    ids = jnp.zeros((t, k), jnp.int32)  # all route to expert 0 (worst case)
    ids = ids.at[:, 1].set(1)
    gates = jnp.ones((t, k)) / k
    x = jax.random.normal(jax.random.PRNGKey(0), (t, 8))
    _, dest, keep, _, _ = _dispatch_group(x, ids, gates, cap, e)
    kept = int(jnp.sum(keep))
    assert kept == 2 * cap  # experts 0 and 1 each keep exactly `cap`


def test_router_aux_losses_finite_and_balanced_router_lower():
    cfg = _cfg()
    from repro.models.moe import init_moe

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux_random = moe_apply(p, x, cfg)
    # a router biased to one expert should have larger load-balance loss
    p_biased = dict(p)
    p_biased["router"] = p["router"].at[:, 0].add(10.0)
    _, aux_biased = moe_apply(p_biased, x, cfg)
    assert float(aux_biased) > float(aux_random)
