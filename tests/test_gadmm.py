"""System tests: GADMM / Q-GADMM convergence and faithfulness (paper Sec. IV-V)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gadmm
from repro.core.baselines import PSProblem, run_adiana, run_gd
from repro.core.quantizer import QuantizerConfig
from repro.core.topology import head_tail_split, random_placement
from repro.data.synthetic import regression_shards


@pytest.fixture(scope="module")
def problem():
    n = 20
    xs, ys, _ = regression_shards(n_workers=n, samples=4000, d=6, seed=1)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    theta_star = jnp.linalg.solve(xtx.sum(0), xty.sum(0))
    return xs, ys, xtx, xty, theta_star


def _run(xs, ys, cfg, iters):
    n = xs.shape[0]
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    st = gadmm.init_state(n, xs.shape[-1], cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
    for _ in range(iters):
        st = step(st)
    return st, q


def test_gadmm_converges_to_optimum(problem):
    xs, ys, _, _, theta_star = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=False)
    st, _ = _run(xs, ys, cfg, 250)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    scale = float(jnp.max(jnp.abs(theta_star)))
    assert err < 2e-2 * max(scale, 1.0), err


def test_qgadmm_2bit_converges_to_optimum(problem):
    """Theorem 2: optimality gap -> 0 with 2-bit stochastic quantization."""
    xs, ys, _, _, theta_star = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    st, _ = _run(xs, ys, cfg, 400)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    scale = float(jnp.max(jnp.abs(theta_star)))
    assert err < 3e-2 * max(scale, 1.0), err


def test_qgadmm_primal_dual_residuals_shrink(problem):
    xs, ys, _, _, _ = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    st = gadmm.init_state(xs.shape[0], xs.shape[-1], cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
    for _ in range(10):
        st = step(st)
    early, _ = gadmm.residuals(st)
    hat_early = st.theta_hat
    for _ in range(290):
        st = step(st)
    late, _ = gadmm.residuals(st)
    assert float(late) < 0.05 * float(early)
    # dual residual proxy: hat changes vanish
    st2 = step(st)
    dual_late = float(jnp.max(jnp.abs(st2.theta_hat - st.theta_hat)))
    assert dual_late < float(jnp.max(jnp.abs(hat_early))) * 0.1


def test_quantized_radius_decreases(problem):
    """The paper's empirical observation justifying fixed bits: R_n^k shrinks."""
    xs, ys, _, _, _ = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    st = gadmm.init_state(xs.shape[0], xs.shape[-1], cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
    for _ in range(5):
        st = step(st)
    r_early = float(jnp.mean(st.radius))
    for _ in range(195):
        st = step(st)
    r_late = float(jnp.mean(st.radius))
    assert r_late < 0.1 * r_early


def test_qgadmm_matches_gadmm_convergence_speed(problem):
    """Headline claim: same rounds-to-accuracy at a fraction of the bits."""
    xs, ys, _, _, theta_star = problem
    iters = 300
    cfg_g = gadmm.GADMMConfig(rho=24.0, quantize=False)
    cfg_q = gadmm.GADMMConfig(rho=24.0, quantize=True, qcfg=QuantizerConfig(bits=2))
    st_g, _ = _run(xs, ys, cfg_g, iters)
    st_q, _ = _run(xs, ys, cfg_q, iters)
    err_g = float(jnp.max(jnp.abs(st_g.theta - theta_star[None])))
    err_q = float(jnp.max(jnp.abs(st_q.theta - theta_star[None])))
    assert err_q < max(3 * err_g, 5e-2)
    n, d = xs.shape[0], xs.shape[-1]
    # at this toy d=6 the always-billed header (the R f32 + b i32 every
    # payload carries, quantizer.header_bits) dominates the 2-bit payload:
    # 20*(2*6+64) vs 20*6*32 is an honest 2.53x
    assert gadmm.bits_per_round(cfg_g, n, d) / gadmm.bits_per_round(cfg_q, n, d) > 2.5
    # the paper's >3.5x communication claim is about payload-dominated
    # model sizes — check it where it applies
    assert gadmm.bits_per_round(cfg_g, n, 1000) / gadmm.bits_per_round(cfg_q, n, 1000) > 3.5


def test_adaptive_bits_mode_converges(problem):
    xs, ys, _, _, theta_star = problem
    cfg = gadmm.GADMMConfig(
        rho=24.0, quantize=True,
        qcfg=QuantizerConfig(bits=2, adapt_bits=True, max_bits=8))
    st, _ = _run(xs, ys, cfg, 400)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    assert err < 5e-2, err


def test_gd_and_adiana_baselines_converge(problem):
    _, _, xtx, xty, theta_star = problem
    prob = PSProblem(xtx=xtx, xty=xty)
    thetas, bits_gd = run_gd(prob, 400)
    assert float(jnp.max(jnp.abs(thetas[-1] - theta_star))) < 1e-2
    ys_ad, bits_ad = run_adiana(prob, 400, bits=2)
    assert float(jnp.max(jnp.abs(ys_ad[-1] - theta_star))) < 5e-2
    assert bits_ad < bits_gd


def test_qgd_converges_near_optimum(problem):
    _, _, xtx, xty, theta_star = problem
    prob = PSProblem(xtx=xtx, xty=xty)
    thetas, _ = run_gd(prob, 400, quantize_bits=2)
    # plain quantized GD has a variance floor; just require rough convergence
    assert float(jnp.max(jnp.abs(thetas[-1] - theta_star))) < 0.3


def test_topology_chain_and_split():
    p = random_placement(50, seed=3)
    assert sorted(p.chain.tolist()) == list(range(50))
    assert p.chain_hop_dist.shape == (49,)
    assert (p.chain_hop_dist < 250 * np.sqrt(2)).all()
    heads, tails = head_tail_split(50)
    assert len(heads) == len(tails) == 25
    assert set(heads) | set(tails) == set(range(50))
    bd = p.broadcast_dist()
    assert bd.shape == (50,)
    assert (bd >= p.chain_hop_dist.min()).all()


def test_time_varying_topology_still_converges(problem):
    """Paper Sec. II: GADMM/Q-GADMM converge under changing neighbors.
    Re-chain every 40 iterations with a random permutation."""
    import numpy as np

    xs, ys, _, _, theta_star = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4))
    n, d = xs.shape[0], xs.shape[-1]
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    st = gadmm.init_state(n, d, cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, cfg=cfg),
                   static_argnames=())
    rng = np.random.default_rng(0)
    for k in range(400):
        if k and k % 40 == 0:
            perm = rng.permutation(n)
            st = gadmm.rechain(st, perm)
            q = gadmm.rechain_quadratic(q, perm, cfg.rho)
        st = step(st, q=q)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    scale = float(jnp.max(jnp.abs(theta_star)))
    assert err < 5e-2 * max(scale, 1.0), err


def test_topk_sparsified_qgadmm_converges():
    """Beyond-paper: top-k sparsified Q-GADMM — the hat-difference scheme acts
    as error feedback, so dropping 75% of coords per round still converges."""
    xs, ys, _ = regression_shards(n_workers=12, samples=2400, d=30, seed=2,
                                  heterogeneous=False)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    theta_star = jnp.linalg.solve(xtx.sum(0), xty.sum(0))
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                            qcfg=QuantizerConfig(bits=4), topk_frac=0.25)
    q = gadmm.make_quadratic(xs, ys, cfg.rho)
    st = gadmm.init_state(12, 30, cfg)
    step = jax.jit(functools.partial(gadmm.gadmm_step, q=q, cfg=cfg))
    for _ in range(400):
        st = step(st)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    scale = float(jnp.max(jnp.abs(theta_star)))
    assert err < 5e-2 * max(scale, 1.0), err
    dense_cfg = gadmm.GADMMConfig(rho=24.0, quantize=True,
                                  qcfg=QuantizerConfig(bits=4))
    assert (gadmm.bits_per_round(cfg, 12, 30)
            < 0.7 * gadmm.bits_per_round(dense_cfg, 12, 30))


# --------------------------------------- state-layout parity property ------
# Guarded like the other property suites (hard import under REPRO_CI=1),
# but per-test rather than per-module: the convergence tier above must run
# on bare checkouts too.
import os  # noqa: E402

if os.environ.get("REPRO_CI") == "1":
    import hypothesis  # noqa: F401  CI promises the property suites: hard fail
_HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare checkouts
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def connected_bipartite(draw):
        """Random connected bipartite graph: a random tree (always both)
        plus up to two cross-parity chords (parity of the tree depth is the
        2-coloring, so a chord between opposite parities stays bipartite)."""
        n = draw(st.integers(min_value=2, max_value=8))
        parents = [draw(st.integers(min_value=0, max_value=i - 1))
                   for i in range(1, n)]
        edges = [(p, i) for i, p in enumerate(parents, start=1)]
        depth = [0] * n
        for i, p in enumerate(parents, start=1):
            depth[i] = depth[p] + 1
        for _ in range(draw(st.integers(min_value=0, max_value=2))):
            u = draw(st.integers(min_value=0, max_value=n - 1))
            v = draw(st.integers(min_value=0, max_value=n - 1))
            e = (min(u, v), max(u, v))
            if u != v and depth[u] % 2 != depth[v] % 2 and e not in edges:
                edges.append(e)
        return n, edges, draw(st.booleans())

    @settings(max_examples=15, deadline=None)
    @given(connected_bipartite())
    def test_graph_step_port_vs_edge_layout_bitwise(scenario):
        """Property: graph_step's O(E) edge-indexed aggregation
        (layout='edge', sorted segment_sum) is BITWISE identical to the
        pre-refactor port-dense operators (layout='port') — same states,
        same censor decisions — on random connected bipartite graphs."""
        from repro.core.censor import CensorConfig
        from repro.core.topology import bipartite_topology

        n, edges, censored = scenario
        topo = bipartite_topology(n, edges)
        d = 3
        xs, ys, _ = regression_shards(n_workers=n, samples=4 * n, d=d,
                                      seed=3)
        cfg = gadmm.GADMMConfig(rho=5.0, quantize=True,
                                qcfg=QuantizerConfig(bits=2))
        cen = CensorConfig(tau=1.0, xi=0.9) if censored else None
        q = gadmm.make_quadratic(jnp.asarray(xs), jnp.asarray(ys), cfg.rho)
        steps = {
            layout: jax.jit(functools.partial(
                gadmm.graph_step, q=q, cfg=cfg, topo=topo, censor=cen,
                layout=layout))
            for layout in ("edge", "port")
        }
        st_e = gadmm.graph_init_state(topo, d, cfg)
        st_p = gadmm.graph_init_state(topo, d, cfg)
        for _ in range(3):
            st_e = steps["edge"](st_e)
            st_p = steps["port"](st_p)
            for field in st_e._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(st_e, field)),
                    np.asarray(getattr(st_p, field)),
                    err_msg=f"n={n} edges={edges} censored={censored} "
                            f"field {field}")
else:  # keep the skip visible in bare-checkout test reports
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_graph_step_port_vs_edge_layout_bitwise():
        pass
