"""Correctness of the §Perf optimization toggles: every optimization must be
numerically equivalent to (or provably a relaxation of) the baseline path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import dense, registry
from repro.models import layers as L


def test_onehot_xent_equals_gather():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 7, 33))
    labels = jax.random.randint(jax.random.PRNGKey(1), (3, 7), 0, 33)
    a = L.softmax_xent(logits, labels, mode="gather")
    b = L.softmax_xent(logits, labels, mode="onehot")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    ga = jax.grad(lambda l: L.softmax_xent(l, labels, mode="gather"))(logits)
    gb = jax.grad(lambda l: L.softmax_xent(l, labels, mode="onehot"))(logits)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), atol=1e-6)


def test_attn_scan_remat_same_loss_and_grads():
    cfg0 = registry.get_config("qwen1.5-4b", smoke=True, attn_q_block=4)
    cfg1 = registry.get_config("qwen1.5-4b", smoke=True, attn_q_block=4,
                               attn_scan_remat=True)
    p = dense.init(jax.random.PRNGKey(0), cfg0)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                     cfg0.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                     cfg0.vocab),
    }
    l0, g0 = jax.value_and_grad(dense.loss_fn)(p, batch, cfg0)
    l1, g1 = jax.value_and_grad(dense.loss_fn)(p, batch, cfg1)
    assert abs(float(l0) - float(l1)) < 1e-6
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_windowed_cache_decode_matches_plain():
    cfg = registry.get_config("gemma3-27b", smoke=True)
    params = dense.init(jax.random.PRNGKey(0), cfg)
    b, t = 2, 20
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    cache_p = dense.init_cache(cfg, b, t)
    cache_w = dense.init_cache_windowed(cfg, b, t)
    for i in range(t):
        pos = jnp.full((b,), i, jnp.int32)
        lp, cache_p = dense.decode_step(params, tokens[:, i], cache_p, pos, cfg)
        lw, cache_w = dense.decode_step(params, tokens[:, i], cache_w, pos, cfg)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lw), atol=2e-4,
                                   rtol=2e-4, err_msg=f"pos {i}")


def test_windowed_cache_size_reduction():
    cfg = registry.get_config("gemma3-27b")
    s = 524288
    plain = cfg.n_layers * s
    n_per = cfg.n_layers // cfg.global_every
    rem = cfg.n_layers - n_per * cfg.global_every
    windowed = (n_per * (cfg.global_every - 1) + rem) * cfg.sliding_window \
        + n_per * s
    assert windowed < plain / 5.5  # ~5.9x fewer KV slots


def test_uneven_sharding_assign():
    from jax.sharding import Mesh, PartitionSpec as P

    devs = np.array(jax.devices() * 16)[:16].reshape(1, 1, 16)
    mesh = Mesh(devs, ("worker", "fsdp", "model"))
    from repro.dist.sharding import _assign

    # 20 heads over 16-way model axis: unsharded normally, padded when uneven
    rule = [(-2, ("model",))]
    assert _assign((2560, 20, 128), rule, mesh) == P(None, None, None)
    assert _assign((2560, 20, 128), rule, mesh, allow_uneven=True) == P(
        None, "model", None)
    # divisible stays exact either way
    assert _assign((2560, 32, 128), rule, mesh, allow_uneven=True) == P(
        None, "model", None)


def test_pack_wire_roundtrip_in_trainer_codec():
    """The pure-jnp wire codec (pack4_ref/unpack4_ref) is exact for b<=4."""
    from repro.kernels.pack.ref import pack4_ref, unpack4_ref

    q = jax.random.randint(jax.random.PRNGKey(0), (4, 1000), 0, 16
                           ).astype(jnp.uint8)
    packed = jax.vmap(pack4_ref)(q)
    assert packed.shape[-1] <= q.shape[-1] // 2 + 256
    back = jax.vmap(lambda p: unpack4_ref(p, 1000))(packed)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))
