"""CI environment guard: property suites must RUN in CI, not silently skip.

The three hypothesis-based modules (test_quantizer / test_comm_model /
test_moe) guard their import with ``pytest.importorskip("hypothesis")`` so
that bare local checkouts still collect.  In CI that skip is a silent hole:
requirements-dev.txt installs hypothesis, but nothing ever failed when the
install regressed and the property suites quietly stopped executing.  The
workflow now exports REPRO_CI=1 on every test step, and under that flag a
missing hypothesis is a hard FAILURE here (and in the property modules
themselves, which import hypothesis unconditionally when REPRO_CI=1).
"""
import importlib.util
import os

import pytest


def _ci() -> bool:
    return os.environ.get("REPRO_CI") == "1"


def test_hypothesis_present_in_ci():
    """REPRO_CI=1 promises the full property suites; hypothesis being
    uninstallable there must fail loudly instead of skipping 3 modules."""
    if not _ci():
        pytest.skip("not a CI environment (REPRO_CI unset)")
    assert importlib.util.find_spec("hypothesis") is not None, (
        "REPRO_CI=1 but hypothesis is not installed: the property suites in "
        "test_quantizer.py / test_comm_model.py / test_moe.py would "
        "silently skip.  Install requirements-dev.txt in the CI test job.")


def test_property_modules_hard_fail_in_ci_without_hypothesis():
    """The property modules themselves must use the REPRO_CI-aware guard —
    plain importorskip would keep skipping even when the flag is set."""
    here = os.path.dirname(__file__)
    for name in ("test_quantizer.py", "test_comm_model.py", "test_moe.py",
                 "test_gadmm.py", "test_sim.py"):
        with open(os.path.join(here, name)) as f:
            src = f.read()
        assert "REPRO_CI" in src, (
            f"{name} must hard-import hypothesis when REPRO_CI=1 instead of "
            "unconditionally calling pytest.importorskip (see the guard "
            "block at the top of the other property modules)")
