"""Capture golden trainer outputs for the cross-refactor bitwise parity tier.

Run from the repo root (PYTHONPATH=src) at a known-good revision:

    PYTHONPATH=src python tests/tools/capture_golden_wire.py

Writes ``tests/golden/wire_state_v1.npz``: the final train state and wire
metrics of the unsharded reference trainer after GOLDEN_STEPS steps on the
canonical MixedModel problem, for every topology x censor x pack combination.
``tests/test_wire_path.py::test_golden_state_bitwise`` replays the same runs
against the current code and asserts bitwise equality — this is what pins
"staleness=0 is bitwise-identical to the pre-refactor trainer" across the
port-dense -> edge-indexed state refactor.

bfloat16 leaves are stored bit-cast to uint16 (npz has no bf16 dtype).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.core.censor import CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import QuantizerConfig
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state

GOLDEN_STEPS = 3
GOLDEN_W = 4
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "..", "golden",
                           "wire_state_v1.npz")


class MixedModel:
    """Mirrors tests/test_wire_path.py: f32 + bf16 + (0,) leaves."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wa": jax.random.normal(k1, (6, 4), jnp.float32),
            "wb": (0.1 * jax.random.normal(k2, (4, 3))).astype(jnp.bfloat16),
            "bias": jax.random.normal(k3, (3,), jnp.float32),
            "empty": jnp.zeros((0,), jnp.float32),
        }

    @staticmethod
    def loss_fn(params, batch, cfg):
        h = batch["x"] @ params["wa"]
        h = h @ params["wb"].astype(jnp.float32) + params["bias"]
        return jnp.mean((h.sum(-1) - batch["y"]) ** 2)


def golden_cases():
    for topology in ("chain", "ring", "star", "torus2d"):
        for censored in (False, True):
            for pack in (False, True):
                yield topology, censored, pack


def golden_run(topology, censored, pack):
    """One unsharded reference run; returns (state, metrics)."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    dcfg = DistConfig(
        num_workers=GOLDEN_W, topology=topology,
        censor=CensorConfig(tau=0.5, xi=0.95) if censored else None,
        pack_wire=pack, wire_impl="jnp",
        gadmm=GADMMConfig(rho=0.5, quantize=True,
                          qcfg=QuantizerConfig(bits=4), alpha=0.01),
        local_iters=2, local_lr=1e-2)
    tr = QGADMMTrainer(MixedModel, None, dcfg, mesh)
    state = init_state(lambda k: MixedModel.init(k, None),
                       jax.random.PRNGKey(0), dcfg)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (GOLDEN_W, 8, 6)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (GOLDEN_W, 8))}
    step = jax.jit(tr.make_train_step())
    for _ in range(GOLDEN_STEPS):
        state, metrics = step(state, batch)
    return tr, state, metrics


def state_arrays(tr, state, metrics):
    """Flatten (state, metrics) into a {name: ndarray} dict in the GOLDEN
    comparison layout: neighbor hats/duals are projected to per-(worker,
    port-color) views so the dict is independent of the internal state
    layout (port-dense tuples pre-refactor, edge slabs post)."""
    out = {}

    def put(name, arr):
        a = np.asarray(arr)
        if arr.dtype == jnp.bfloat16:
            a = np.asarray(arr).view(np.uint16)
            name += "#bf16"
        out[name] = a

    views = tr.port_views(state) if hasattr(tr, "port_views") else {
        "hat_nbr": state.hat_nbr, "lam_nbr": state.lam_nbr}
    # edge-indexed states project their slabs to the golden port-view names
    alias = {"hat_edge": "hat_nbr", "lam_edge": "lam_nbr"}
    for field in state._fields:
        name = alias.get(field, field)
        val = views.get(name, getattr(state, field))
        for i, leaf in enumerate(jax.tree.leaves(val)):
            put(f"{name}.{i}", leaf)
    for k in ("loss", "skip_rate", "wire_bits_per_round"):
        out[f"metric.{k}"] = np.asarray(metrics[k])
    return out


def main():
    blob = {}
    for topology, censored, pack in golden_cases():
        tag = f"{topology}|c{int(censored)}|p{int(pack)}"
        tr, state, metrics = golden_run(topology, censored, pack)
        for name, arr in state_arrays(tr, state, metrics).items():
            blob[f"{tag}|{name}"] = arr
        print("captured", tag, "loss", float(metrics["loss"]))
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    np.savez_compressed(GOLDEN_PATH, **blob)
    print("wrote", GOLDEN_PATH, len(blob), "arrays")


if __name__ == "__main__":
    main()
