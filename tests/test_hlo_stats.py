"""Calibration tests for the HLO accounting used by the roofline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_stats


def test_hlo_cost_exact_on_scan_of_matmuls():
    def g(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jnp.zeros((128, 256))
    w = jnp.zeros((7, 256, 256))
    txt = jax.jit(g).lower(x, w).compile().as_text()
    cost = hlo_stats.hlo_cost(txt)
    expected = 7 * 2 * 128 * 256 * 256
    assert abs(cost["flops"] - expected) / expected < 1e-6


def test_hlo_cost_counts_plain_dot():
    f = lambda a, b: a @ b
    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    txt = jax.jit(f).lower(a, b).compile().as_text()
    cost = hlo_stats.hlo_cost(txt)
    assert cost["flops"] == 2 * 64 * 128 * 32


def test_shape_bytes():
    assert hlo_stats._shape_bytes("f32[2,3]{1,0}") == 24
    assert hlo_stats._shape_bytes("bf16[10]") == 20
    assert hlo_stats._shape_bytes("u8[100]{0}") == 100
    assert hlo_stats._shape_bytes("(f32[2], u8[4])") == 12


def test_collective_stats_on_psum():
    import subprocess  # noqa: F401  (documentational)

    # single-device module: no collectives
    txt = jax.jit(lambda x: x + 1).lower(jnp.zeros((4,))).compile().as_text()
    stats = hlo_stats.collective_stats(txt)
    assert stats.total_bytes == 0


def test_trip_count_multiplier_parsing():
    # scan of 5 adds: the while body should get multiplier 5 when the
    # backend_config advertises known_trip_count
    def f(x):
        def body(c, _):
            return c * 1.5 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    txt = jax.jit(f).lower(jnp.zeros((8, 128))).compile().as_text()
    if "known_trip_count" in txt:
        blocks = hlo_stats._computation_blocks(txt)
        mults = hlo_stats._reach_multipliers(blocks, txt)
        assert max(mults.values()) >= 5
