"""Per-kernel allclose sweeps vs the pure-jnp ref.py oracles (interpret mode)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pack import ops as pack_ops
from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize import quantize as q_kernel
from repro.kernels.quantize import ref as q_ref

SHAPES = [(7,), (128,), (1000,), (31, 33), (4, 256, 17), (2048, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]
BITS = [1, 2, 4, 8]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [2, 8])
def test_quantize_matches_ref(shape, dtype, bits):
    key = jax.random.PRNGKey(zlib.crc32(repr((shape, str(dtype), bits)).encode()) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jax.random.normal(k1, shape).astype(dtype)
    hat = (0.5 * jax.random.normal(k2, shape)).astype(dtype)
    r = jnp.max(jnp.abs(theta.astype(jnp.float32) - hat.astype(jnp.float32)))
    q_p, hat_p = q_ops.quantize_dequantize(theta, hat, k3, r, bits, impl="pallas")
    q_r, hat_r = q_ops.quantize_dequantize(theta, hat, k3, r, bits, impl="ref")
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))
    # hat can differ by ~1 f32 ULP (FMA association inside the fused kernel),
    # which may land on a bf16 rounding boundary -> allow 1 bf16 ULP.
    atol = 2e-5 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(
        np.asarray(hat_p, np.float32), np.asarray(hat_r, np.float32), atol=atol
    )


@pytest.mark.parametrize("bits", BITS)
def test_quantize_error_bound(bits):
    """|theta_hat - theta| <= Delta = 2R/(2^b - 1) elementwise."""
    key = jax.random.PRNGKey(bits)
    theta = jax.random.normal(key, (4096,))
    hat0 = jnp.zeros_like(theta)
    r = jnp.max(jnp.abs(theta))
    _, hat = q_ops.quantize_dequantize(theta, hat0, jax.random.PRNGKey(1), r, bits)
    delta = 2 * r / (2**bits - 1)
    assert float(jnp.max(jnp.abs(hat - theta))) <= float(delta) + 1e-5


def test_quantize_zero_radius_is_identity():
    theta = jnp.ones((257,))
    hat = jnp.ones((257,))
    r = jnp.zeros(())
    q, new_hat = q_ops.quantize_dequantize(theta, hat, jax.random.PRNGKey(0), r, 2)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_allclose(np.asarray(new_hat), np.asarray(hat))


def test_quantize_levels_in_range():
    theta = jax.random.normal(jax.random.PRNGKey(0), (999,))
    hat = jnp.zeros_like(theta)
    r = jnp.max(jnp.abs(theta))
    for bits in BITS:
        q, _ = q_ops.quantize_dequantize(theta, hat, jax.random.PRNGKey(1), r, bits)
        assert int(jnp.max(q)) <= 2**bits - 1


def test_quantize_sender_receiver_consistency():
    """Receiver reconstruction from (q, R, b) equals sender's new hat exactly."""
    from repro.core import quantizer as Q

    theta = jax.random.normal(jax.random.PRNGKey(5), (1234,))
    hat0 = 0.3 * jax.random.normal(jax.random.PRNGKey(6), (1234,))
    r = jnp.max(jnp.abs(theta - hat0))
    bits = jnp.asarray(4, jnp.int32)
    q, hat_sender = Q.quantize_tensor(
        theta, hat0, jax.random.PRNGKey(7), radius=r, bits=bits
    )
    hat_receiver = Q.dequantize_tensor(q, hat0, radius=r, bits=bits)
    np.testing.assert_allclose(np.asarray(hat_sender), np.asarray(hat_receiver), atol=0)


@pytest.mark.parametrize("n", [1, 2, 255, 256, 257, 999, 65536, 70000])
def test_pack_roundtrip_and_ref(n):
    q = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 16).astype(jnp.uint8)
    pk = pack_ops.pack4(q)
    pk_ref = pack_ops.pack4(q, impl="ref")
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pk_ref))
    un = pack_ops.unpack4(pk, n)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(q))
    un_ref = pack_ops.unpack4(pk_ref, n, impl="ref")
    np.testing.assert_array_equal(np.asarray(un_ref), np.asarray(q))
    assert pk.size <= n // 2 + 256  # ~2x compression (+ row padding)


@pytest.mark.parametrize("n", [1, 3, 127, 129, 250, 257, 300, 511, 1000,
                               4097, 70001])
def test_packed_len_is_the_wire_length_contract(n):
    """packed_len(n) (exported by kernels/pack) IS the wire length both the
    packer and every unpacker must agree on, including every odd size with
    n % 256 != 0 — regression: the dist trainer used to hardcode the
    128 * ceil(n/256) formula."""
    from repro.kernels.pack.ref import LANES, _pad_rows

    assert pack_ops.packed_len(n) == 128 * (-(-n // 256)) == LANES * _pad_rows(n)
    q = jax.random.randint(jax.random.PRNGKey(n), (n,), 0, 16).astype(jnp.uint8)
    for impl in ("ref", "pallas"):
        pk = pack_ops.pack4(q, impl=impl)
        assert pk.size == pack_ops.packed_len(n), (impl, n)
        un = pack_ops.unpack4(pk[: pack_ops.packed_len(n)], n, impl=impl)
        np.testing.assert_array_equal(np.asarray(un), np.asarray(q))


@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_vector_radius_matches_ref(dtype):
    """Per-element radius (the trainer's per_tensor segment-scalar expansion)
    agrees between the Pallas tile-radius kernel and the broadcasting ref."""
    key = jax.random.PRNGKey(11)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 700  # odd size: exercises radius padding in the tile path
    theta = jax.random.normal(k1, (n,)).astype(dtype)
    hat = (0.5 * jax.random.normal(k2, (n,))).astype(dtype)
    # two "tensors" of 300 + 400 elements with their own radii; one zero
    diff = jnp.abs(theta.astype(jnp.float32) - hat.astype(jnp.float32))
    r_a = jnp.max(diff[:300])
    radius = jnp.concatenate([jnp.full((300,), r_a),
                              jnp.zeros((400,), jnp.float32)])
    u = jax.random.uniform(k3, (n,), jnp.float32)
    levels = jnp.asarray(15.0)
    q_r, hat_r = q_ref.quantize_dequantize_ref(theta, hat, u, radius, levels)
    q_p, hat_p = q_kernel.quantize_dequantize(theta, hat, u, radius, levels,
                                              interpret=True)
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_p))
    np.testing.assert_array_equal(np.asarray(hat_r, np.float32),
                                  np.asarray(hat_p, np.float32))
    # zero-radius segment: untouched hat, all-zero levels
    np.testing.assert_array_equal(np.asarray(q_p[300:]), 0)
    np.testing.assert_array_equal(np.asarray(hat_p[300:]),
                                  np.asarray(hat[300:]))


@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_vector_levels_matches_ref(dtype):
    """Per-element levels (the trainer's layerwise per-leaf bit widths) agree
    bitwise between the Pallas tile kernel and the ref — under jit on both
    sides: eager XLA fuses the step arithmetic differently (FMA), so the
    parity contract is jitted-ref == kernel, which is also how the trainer
    runs both impls."""
    key = jax.random.PRNGKey(13)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 700
    theta = jax.random.normal(k1, (n,)).astype(dtype)
    hat = (0.5 * jax.random.normal(k2, (n,))).astype(dtype)
    diff = jnp.abs(theta.astype(jnp.float32) - hat.astype(jnp.float32))
    # three "leaves" of 300 + 300 + 100 elements: own radius AND own bits,
    # the last one masked out (radius 0 = unsent leaf)
    radius = jnp.concatenate([jnp.full((300,), jnp.max(diff[:300])),
                              jnp.full((300,), jnp.max(diff[300:600])),
                              jnp.zeros((100,), jnp.float32)])
    levels = jnp.concatenate([jnp.full((300,), 15.0),
                              jnp.full((300,), 3.0),
                              jnp.ones((100,), jnp.float32)])
    u = jax.random.uniform(k3, (n,), jnp.float32)
    q_r, hat_r = jax.jit(q_ref.quantize_dequantize_ref)(
        theta, hat, u, radius, levels)
    q_p, hat_p = jax.jit(
        lambda *a: q_kernel.quantize_dequantize(*a, interpret=True))(
        theta, hat, u, radius, levels)
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_p))
    np.testing.assert_array_equal(
        np.asarray(hat_r, np.float32).view(np.uint8),
        np.asarray(hat_p, np.float32).view(np.uint8))
    assert int(jnp.max(q_p[:300])) <= 15 and int(jnp.max(q_p[300:600])) <= 3
    # masked leaf: q == 0 and hat untouched
    np.testing.assert_array_equal(np.asarray(q_p[600:]), 0)
    np.testing.assert_array_equal(np.asarray(hat_p[600:]),
                                  np.asarray(hat[600:]))


SEGS = [  # (sizes, bits) mixed-width framing cases
    ((256,), (4,)),
    ((100, 200), (2, 8)),
    ((7, 0, 300, 65), (8, 4, 3, 5)),
    ((0, 0), (1, 8)),
    ((1000, 1, 129), (4, 1, 6)),
]


@pytest.mark.parametrize("sizes,bits", SEGS)
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_pack_mixed_roundtrip(sizes, bits, impl):
    """pack_mixed/unpack_mixed round-trip under the static (size, bits)
    framing, with mixed_packed_len as the wire-length contract; zero-size
    segments contribute no bytes (regression: the pack4 kernel divides by
    zero on an empty input)."""
    n = sum(sizes)
    key = jax.random.PRNGKey(n + 1)
    segs = []
    for i, (sz, b) in enumerate(zip(sizes, bits)):
        segs.append(jax.random.randint(jax.random.fold_in(key, i), (sz,),
                                       0, 2 ** b).astype(jnp.uint8))
    q = jnp.concatenate(segs) if segs else jnp.zeros((0,), jnp.uint8)
    pk = pack_ops.pack_mixed(q, sizes, bits, impl=impl)
    assert pk.size == pack_ops.mixed_packed_len(sizes, bits), (sizes, bits)
    un = pack_ops.unpack_mixed(pk, sizes, bits, impl=impl)
    np.testing.assert_array_equal(np.asarray(un), np.asarray(q))


@pytest.mark.parametrize("sizes,bits", SEGS)
def test_pack_mixed_impl_parity(sizes, bits):
    """ref and pallas produce byte-identical mixed wire buffers."""
    n = sum(sizes)
    q = jax.random.randint(jax.random.PRNGKey(n + 2), (n,), 0, 2).astype(
        jnp.uint8)
    pk_r = pack_ops.pack_mixed(q, sizes, bits, impl="ref")
    pk_p = pack_ops.pack_mixed(q, sizes, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(pk_r), np.asarray(pk_p))


def test_mixed_packed_len_formula():
    """<=4-bit segments pay the pack4 nibble format (128*ceil(n/256) bytes),
    wider segments one byte per element, zero-size segments nothing."""
    assert pack_ops.mixed_packed_len((), ()) == 0
    assert pack_ops.mixed_packed_len((0,), (4,)) == 0
    assert pack_ops.mixed_packed_len((256,), (4,)) == 128
    assert pack_ops.mixed_packed_len((257,), (4,)) == 256
    assert pack_ops.mixed_packed_len((257,), (5,)) == 257
    assert pack_ops.mixed_packed_len((100, 200), (2, 8)) == 128 + 200


def test_kernel_block_shape_alignment():
    """Kernel tiles are (m,128) lane-aligned for every input size."""
    for n in (1, 127, 128, 129, 12345):
        theta = jnp.arange(n, dtype=jnp.float32)
        hat = jnp.zeros_like(theta)
        r = jnp.max(jnp.abs(theta))
        q, hat_new = q_kernel.quantize_dequantize(
            theta, hat, jnp.ones_like(theta), r, jnp.asarray(3.0), interpret=True
        )
        assert q.shape == theta.shape and hat_new.shape == theta.shape
