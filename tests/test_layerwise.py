"""Layerwise (L-FGADMM) per-leaf wire contracts of the distributed trainer.

Per-leaf bit widths / exchange periods / censor thresholds and the adaptive
bit-budget controller: uniform-defaults equivalence, jnp vs pallas bitwise
parity composed with censoring / staleness / participation, period masking
semantics (receiver holds the last hat), budget conservation, eq. 11 per-leaf
adaptation, and the layerwise wire accounting against its closed form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.censor import FLAG_BITS, CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import (LayerwiseConfig, QuantizerConfig,
                                  allocate_bits, header_bits)
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
from repro.kernels.pack.ref import packed_len

# MixedModel leaf order (jax.tree.leaves of the params dict, sorted keys):
# bias (3,), empty (0,), wa (24,), wb (12,) -> per-leaf tuples index this.
LEAF_SIZES = (3, 0, 24, 12)


class MixedModel:
    """Mixed-precision pytree: f32 + bf16 leaves plus a zero-size leaf."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wa": jax.random.normal(k1, (6, 4), jnp.float32),
            "wb": (0.1 * jax.random.normal(k2, (4, 3))).astype(jnp.bfloat16),
            "bias": jax.random.normal(k3, (3,), jnp.float32),
            "empty": jnp.zeros((0,), jnp.float32),
        }

    @staticmethod
    def loss_fn(params, batch, cfg):
        h = batch["x"] @ params["wa"]
        h = h @ params["wb"].astype(jnp.float32) + params["bias"]
        return jnp.mean((h.sum(-1) - batch["y"]) ** 2)


def _setup(w=4, **dcfg_kw):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    kw = dict(num_workers=w,
              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                qcfg=QuantizerConfig(bits=4), alpha=0.01),
              local_iters=2, local_lr=1e-2)
    kw.update(dcfg_kw)
    dcfg = DistConfig(**kw)
    tr = QGADMMTrainer(MixedModel, None, dcfg, mesh)
    state = init_state(lambda k: MixedModel.init(k, None),
                       jax.random.PRNGKey(0), dcfg)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (w, 8, 6)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (w, 8))}
    return tr, state, batch


def _run(tr, state, batch, steps=3):
    step = jax.jit(tr.make_train_step())
    metrics = None
    for _ in range(steps):
        state, metrics = step(state, batch)
    return state, metrics


def _assert_states_equal(st_a, st_b, fields=None):
    for field in fields or st_a._fields:
        la = jax.tree.leaves(getattr(st_a, field))
        lb = jax.tree.leaves(getattr(st_b, field))
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8) if a.dtype == jnp.bfloat16
                else np.asarray(a),
                np.asarray(b).view(np.uint8) if b.dtype == jnp.bfloat16
                else np.asarray(b),
                err_msg=f"state field {field} diverged")


def test_layerwise_defaults_equal_uniform():
    """LayerwiseConfig() (all periods 1, bits from QuantizerConfig, no
    thresholds) reproduces the uniform per_tensor trajectory bitwise — the
    per-leaf codec path is the same arithmetic when every leaf looks alike."""
    tr_u, st_u, batch = _setup(radius_mode="per_tensor")
    tr_l, st_l, _ = _setup(layerwise=LayerwiseConfig())
    st_u, m_u = _run(tr_u, st_u, batch)
    st_l, m_l = _run(tr_l, st_l, batch)
    # bits differ in shape ((W,) vs (W, L)) by design; everything else and
    # the model trajectory must match bitwise
    _assert_states_equal(st_u, st_l, fields=("theta", "theta_hat",
                                             "hat_edge", "lam_edge",
                                             "radius"))
    np.testing.assert_array_equal(np.asarray(m_u["loss"]),
                                  np.asarray(m_l["loss"]))
    assert st_l.bits.shape == (4, len(LEAF_SIZES))
    np.testing.assert_array_equal(np.asarray(st_l.bits), 4)


LW = LayerwiseConfig(bits=(4, 2, 3, 1), periods=(1, 2, 3, 1), taus=1e-6)
COMPOSITIONS = [
    dict(),
    dict(censor=CensorConfig(tau=1e-3, xi=0.9)),
    dict(staleness=1, participation=0.75),
]


@pytest.mark.parametrize("extra", COMPOSITIONS,
                         ids=["plain", "censor", "stale_partial"])
@pytest.mark.parametrize("pack_wire", [False, True])
def test_layerwise_parity_jnp_vs_pallas(extra, pack_wire):
    """Per-leaf bits x periods x taus composed with censoring / staleness /
    participation: wire_impl='pallas' is bit-identical to 'jnp' through
    whole train steps (the shared uniform-draw convention extends to the
    per-element-levels kernel path)."""
    tr_j, st_j, batch = _setup(layerwise=LW, pack_wire=pack_wire,
                               wire_impl="jnp", **extra)
    tr_p, st_p, _ = _setup(layerwise=LW, pack_wire=pack_wire,
                           wire_impl="pallas", **extra)
    st_j, m_j = _run(tr_j, st_j, batch)
    st_p, m_p = _run(tr_p, st_p, batch)
    _assert_states_equal(st_j, st_p)
    np.testing.assert_array_equal(np.asarray(m_j["loss"]),
                                  np.asarray(m_p["loss"]))
    np.testing.assert_array_equal(np.asarray(m_j["wire_bits_per_round"]),
                                  np.asarray(m_p["wire_bits_per_round"]))


def test_layerwise_periods_hold_last_hat():
    """A leaf with period P is transmitted only on rounds where
    step % P == 0; in between, both endpoints hold its last hat (and the
    round's wire bill drops by the silent leaf's payload)."""
    # wa (index 2 in leaf order) transmits on even steps only
    tr, st, batch = _setup(layerwise=LayerwiseConfig(periods=(1, 1, 2, 1)))
    step = jax.jit(tr.make_train_step())
    st1, m1 = step(st, batch)     # round 0: all leaves due
    st2, m2 = step(st1, batch)    # round 1: wa silent
    st3, m3 = step(st2, batch)    # round 2: all leaves due again
    np.testing.assert_array_equal(np.asarray(st2.theta_hat["wa"]),
                                  np.asarray(st1.theta_hat["wa"]))
    assert np.any(np.asarray(st3.theta_hat["wa"])
                  != np.asarray(st2.theta_hat["wa"]))
    # silent leaf also keeps its committed radius and bits rows
    np.testing.assert_array_equal(np.asarray(st2.radius[:, 2]),
                                  np.asarray(st1.radius[:, 2]))
    assert float(m2["wire_bits_per_round"]) < float(m1["wire_bits_per_round"])
    assert float(m3["wire_bits_per_round"]) == float(
        m1["wire_bits_per_round"])


def test_layerwise_wire_accounting_closed_form():
    """With every leaf due and nothing censored, the layerwise metric equals
    the closed form: per phase, every directed edge carries L 1-bit flags
    and each worker's transmission bills deg(w) * sum_l (8 * bytes_l +
    header_bits()) on the mixed pack format (packed_len at <= 4 bits)."""
    tr, st, batch = _setup(layerwise=LayerwiseConfig())
    _, m = _run(tr, st, batch, steps=1)
    n_edges, n_leaves = 3, len(LEAF_SIZES)          # chain of 4 workers
    deg = (1, 2, 2, 1)
    per_leaf = [8 * packed_len(n) + header_bits() for n in LEAF_SIZES]
    expect = (2 * (2 * n_edges * n_leaves * FLAG_BITS)   # 2 g-s phases
              + sum(deg) * sum(per_leaf))
    assert float(m["wire_bits_per_round"]) == float(expect)


def test_allocate_bits_contract():
    """Controller invariants: floor at min_bits, range respected, budget
    conserved, and strictly better-scored leaves never get fewer bits."""
    sizes = np.asarray(LEAF_SIZES, np.float32)
    scores = jnp.asarray([0.5, 0.0, 3.0, 1.0])
    for budget in (0, 39, 100, 150, 10_000):
        b = allocate_bits(scores, sizes, budget, 1, 8)
        assert b.shape == scores.shape and b.dtype == jnp.int32
        assert int(jnp.min(b)) >= 1 and int(jnp.max(b)) <= 8
        spend = float(jnp.sum(b * sizes))
        assert spend <= max(budget, 1 * float(sizes.sum())) + 1e-6
    b = allocate_bits(scores, sizes, 150, 1, 8)
    order = np.argsort(-np.asarray(scores))
    bs = np.asarray(b)[order]
    assert all(bs[i] >= bs[i + 1] or sizes[order][i] > sizes[order][i + 1]
               for i in range(len(bs) - 1))
    # batched scores allocate row-wise
    b2 = allocate_bits(jnp.stack([scores, scores[::-1]]), sizes, 150, 1, 8)
    assert b2.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(b2[0]), np.asarray(b))


def test_bit_budget_conservation_in_trainer():
    """With budget_bits set, every worker's committed per-leaf widths spend
    at most max(budget, min_bits * d) payload bits per transmission."""
    budget = 100
    tr, st, batch = _setup(layerwise=LayerwiseConfig(budget_bits=budget))
    st, m = _run(tr, st, batch)
    sizes = np.asarray(LEAF_SIZES, np.float32)
    bits = np.asarray(st.bits)
    assert bits.shape == (4, len(LEAF_SIZES))
    assert bits.min() >= 1 and bits.max() <= 8
    spend = (bits * sizes).sum(axis=1)
    assert np.all(spend <= max(budget, sizes.sum())), spend
    assert np.isfinite(float(m["loss"]))


def test_layerwise_adapt_bits_eq11():
    """adapt_bits=True applies the eq. 11 growth rule per leaf: committed
    widths stay in [min_bits, max_bits] with per-leaf (W, L) state."""
    lw = LayerwiseConfig(adapt_bits=True, max_bits=6)
    tr, st, batch = _setup(layerwise=lw)
    st, m = _run(tr, st, batch)
    bits = np.asarray(st.bits)
    assert bits.shape == (4, len(LEAF_SIZES))
    assert bits.min() >= lw.min_bits and bits.max() <= lw.max_bits
    assert np.isfinite(float(m["loss"]))
