"""End-to-end convergence regression (paper Fig. 2 shape + CQ-GGADMM).

Small fixed-seed linear regression.  Locks in, on CPU in well under 120 s:

  * Q-GADMM matches GADMM's objective within tolerance in <= N rounds
    (the headline same-rounds-to-accuracy claim, Fig. 2),
  * censored Q-GADMM matches BOTH within 1e-3 relative gap while totalling
    >= 25 % fewer wire bits (it actually saves ~75 % here),
  * the same holds through the distributed trainer's wire_bits_per_round
    accounting, with a substantial measured skip rate,
  * every generalized topology (ring / star / 2d-torus) converges to the
    same optimum through the graph reference.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import gadmm
from repro.core.censor import CensorConfig
from repro.core.quantizer import QuantizerConfig
from repro.core.topology import build_topology, chain_topology
from repro.data.synthetic import regression_shards

N_WORKERS, DIM, ROUNDS = 12, 6, 300


@pytest.fixture(scope="module")
def problem():
    xs, ys, _ = regression_shards(n_workers=N_WORKERS, samples=2400, d=DIM,
                                  seed=1)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xtx = jnp.einsum("nmd,nme->nde", xs, xs)
    xty = jnp.einsum("nmd,nm->nd", xs, ys)
    theta_star = jnp.linalg.solve(xtx.sum(0), xty.sum(0))
    return xs, ys, theta_star


def _run_graph(problem, topo, *, quantize=True, censor=None, rounds=ROUNDS,
               bits=2, trace_every=0):
    xs, ys, _ = problem
    cfg = gadmm.GADMMConfig(rho=24.0, quantize=quantize,
                            qcfg=QuantizerConfig(bits=bits))
    q = gadmm.make_graph_quadratic(xs, ys, cfg.rho, topo)
    st = gadmm.graph_init_state(topo, DIM, cfg)
    step = jax.jit(functools.partial(gadmm.graph_step, q=q, cfg=cfg,
                                     topo=topo, censor=censor))
    total_bits = 0.0
    trace = []
    for k in range(rounds):
        st = step(st)
        total_bits += float(gadmm.graph_bits_per_round(
            cfg, topo, DIM, st.sent, censored=censor is not None))
        if trace_every and k % trace_every == 0:
            trace.append(float(q.objective(st.theta)))
    return st, q, total_bits, trace


def test_qgadmm_matches_gadmm_objective_fig2(problem):
    """Fig. 2 shape: 2-bit Q-GADMM reaches GADMM's objective in the same
    <= ROUNDS budget, and the objective decreases monotonically at the
    traced resolution."""
    topo = chain_topology(N_WORKERS)
    st_g, q, _, _ = _run_graph(problem, topo, quantize=False)
    st_q, _, _, trace = _run_graph(problem, topo, quantize=True,
                                   trace_every=25)
    f_g = float(q.objective(st_g.theta))
    f_q = float(q.objective(st_q.theta))
    assert abs(f_q - f_g) / abs(f_g) < 1e-3, (f_q, f_g)
    # objective error decays along the run (Fig. 2's y-axis), never blows up
    assert trace[-1] <= trace[0]
    assert all(b <= a + 1e-3 * abs(a) for a, b in zip(trace, trace[1:])), \
        trace


def test_censored_qgadmm_matches_with_fewer_bits(problem):
    """Acceptance: censored Q-GADMM within 1e-3 relative objective gap of
    both GADMM and uncensored Q-GADMM, at >= 25 % fewer total wire bits
    (against the uncensored Q-GADMM accounting)."""
    topo = chain_topology(N_WORKERS)
    st_g, q, _, _ = _run_graph(problem, topo, quantize=False)
    st_q, _, bits_q, _ = _run_graph(problem, topo, quantize=True)
    st_c, _, bits_c, _ = _run_graph(
        problem, topo, quantize=True, censor=CensorConfig(tau=1.0, xi=0.98))
    f_g = float(q.objective(st_g.theta))
    f_q = float(q.objective(st_q.theta))
    f_c = float(q.objective(st_c.theta))
    assert abs(f_c - f_q) / abs(f_q) < 1e-3, (f_c, f_q)
    assert abs(f_c - f_g) / abs(f_g) < 1e-3, (f_c, f_g)
    assert bits_c < 0.75 * bits_q, (bits_c, bits_q)  # >= 25 % lower
    # the mechanism really fires: a large share of rounds stayed silent
    assert bits_c < 0.5 * bits_q


@pytest.mark.parametrize("kind", ["ring", "star", "torus2d"])
def test_generalized_topologies_reach_the_optimum(problem, kind):
    """CQ-GGADMM's generalized graphs: the same sweep on ring / star /
    2d-torus converges to the global least-squares solution."""
    _, _, theta_star = problem
    topo = build_topology(kind, N_WORKERS)
    st, q, _, _ = _run_graph(problem, topo, quantize=True, bits=4,
                             rounds=200)
    err = float(jnp.max(jnp.abs(st.theta - theta_star[None])))
    scale = float(jnp.max(jnp.abs(theta_star)))
    assert err < 5e-2 * max(scale, 1.0), (kind, err)


class _LinReg:
    """Tiny linreg module for the distributed trainer."""

    @staticmethod
    def init(key, cfg):
        return {"w": jnp.zeros((8,)), "b": jnp.zeros(())}

    @staticmethod
    def loss_fn(params, batch, cfg):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)


def test_dist_trainer_censoring_saves_wire_bits():
    """Acceptance, through the distributed trainer: censored training reaches
    the uncensored objective within 1e-3 relative gap while the summed
    wire_bits_per_round metric is >= 25 % lower, with a real measured skip
    rate (not just the active-sender accounting refinement)."""
    from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state

    w = 4
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=8)
    x = rng.normal(size=(w, 32, 8))
    y = x @ w_true + 0.1 * rng.normal(size=(w, 32))
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    xf, yf = jnp.asarray(x.reshape(-1, 8)), jnp.asarray(y.reshape(-1))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))

    def objective(st):
        wbar = jnp.mean(st.theta["w"], axis=0)
        bbar = jnp.mean(st.theta["b"])
        return float(jnp.mean((xf @ wbar + bbar - yf) ** 2))

    def run(censor, steps=120):
        dcfg = DistConfig(
            num_workers=w, censor=censor,
            gadmm=gadmm.GADMMConfig(rho=0.1, quantize=True,
                                    qcfg=QuantizerConfig(bits=4), alpha=0.1),
            local_iters=5, local_lr=5e-2)
        tr = QGADMMTrainer(_LinReg, None, dcfg, mesh)
        st = init_state(lambda k: _LinReg.init(k, None),
                        jax.random.PRNGKey(0), dcfg)
        step = jax.jit(tr.make_train_step())
        bits = 0.0
        skips = []
        for _ in range(steps):
            st, m = step(st, batch)
            bits += float(m["wire_bits_per_round"])
            skips.append(float(m["skip_rate"]))
        return st, bits, float(np.mean(skips))

    st_u, bits_u, skip_u = run(None)
    st_c, bits_c, skip_c = run(CensorConfig(tau=0.3, xi=0.95))
    f_u, f_c = objective(st_u), objective(st_c)
    assert abs(f_c - f_u) / abs(f_u) < 1e-3, (f_c, f_u)
    assert bits_c < 0.75 * bits_u, (bits_c, bits_u)  # >= 25 % lower
    assert skip_u == 0.0
    assert skip_c > 0.5, skip_c  # censoring genuinely fires
