"""Property-based tests of the stochastic quantizer invariants (hypothesis)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("REPRO_CI") == "1":
    import hypothesis  # noqa: F401  CI promises the property suites: hard fail
else:
    pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantizer as Q

jax.config.update("jax_enable_x64", False)


@st.composite
def tensor_and_bits(draw):
    n = draw(st.integers(min_value=1, max_value=512))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    bits = draw(st.sampled_from([1, 2, 3, 4, 8]))
    scale = draw(st.floats(min_value=1e-3, max_value=1e3))
    return n, seed, bits, scale


@settings(max_examples=40, deadline=None)
@given(tensor_and_bits())
def test_error_bounded_by_step(args):
    n, seed, bits, scale = args
    key = jax.random.PRNGKey(seed)
    theta = scale * jax.random.normal(key, (n,))
    hat0 = jnp.zeros_like(theta)
    r = jnp.max(jnp.abs(theta))
    q, hat = Q.quantize_tensor(
        theta, hat0, jax.random.PRNGKey(seed + 1), radius=r,
        bits=jnp.asarray(bits, jnp.int32),
    )
    step = 2 * float(r) / (2**bits - 1)
    err = float(jnp.max(jnp.abs(hat - theta)))
    assert err <= step + 1e-4 * step + 1e-30
    assert int(q.max()) <= 2**bits - 1


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_unbiasedness(seed):
    """E[theta_hat] == theta: average many independent stochastic roundings."""
    key = jax.random.PRNGKey(seed)
    theta = jax.random.normal(key, (16,))
    hat0 = jnp.zeros_like(theta)
    r = jnp.max(jnp.abs(theta))
    reps = 4000

    def one(k):
        _, hat = Q.quantize_tensor(
            theta, hat0, k, radius=r, bits=jnp.asarray(2, jnp.int32)
        )
        return hat

    hats = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(seed + 1), reps))
    mean = jnp.mean(hats, axis=0)
    step = 2 * r / 3
    # std of mean ~ step/2/sqrt(reps); allow 5 sigma
    tol = 5 * float(step) / 2 / np.sqrt(reps)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(theta), atol=tol)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_pytree_quantize_roundtrip_sync(seed):
    """Sender state and receiver reconstruction stay identical across steps."""
    cfg = Q.QuantizerConfig(bits=3)
    key = jax.random.PRNGKey(seed)
    theta = {
        "w": jax.random.normal(key, (8, 5)),
        "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (5,)),
    }
    sender = Q.init_state(theta, cfg)
    receiver_hat = jax.tree.map(jnp.zeros_like, theta)
    for step in range(4):
        k = jax.random.PRNGKey(seed + 10 + step)
        payload, sender = Q.quantize(theta, sender, k, cfg)
        receiver_hat = Q.dequantize(payload, receiver_hat)
        for a, b in zip(jax.tree.leaves(sender.theta_hat), jax.tree.leaves(receiver_hat)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # drift theta a little, as training would
        theta = jax.tree.map(lambda x: 0.9 * x, theta)


def test_bit_growth_rule():
    """Eq. 11: bits grow exactly enough to keep Delta non-increasing."""
    cfg = Q.QuantizerConfig(bits=2, adapt_bits=True, max_bits=8)
    b_prev = jnp.asarray(2, jnp.int32)
    # R doubles => need Delta_new <= Delta_old => 2R/(2^b-1) <= 2R_old/(2^b_prev-1)
    b = Q._next_bits(cfg, b_prev, jnp.asarray(2.0), jnp.asarray(1.0))
    lev_prev, lev_new = 2**2 - 1, 2 ** int(b) - 1
    assert 2 * 2.0 / lev_new <= 2 * 1.0 / lev_prev + 1e-6
    # R shrinks => bits may stay at 1..2, Delta still non-increasing
    b2 = Q._next_bits(cfg, b_prev, jnp.asarray(0.25), jnp.asarray(1.0))
    assert 2 * 0.25 / (2 ** int(b2) - 1) <= 2 * 1.0 / lev_prev + 1e-6


def test_payload_bits():
    """Header = 32 (R) + 32 (bits), unconditionally: the payload dict always
    carries `bits`, so it is always billed — one rule, shared with
    gadmm.bits_per_round and dist.qgadmm.wire_bits_per_round."""
    cfg = Q.QuantizerConfig(bits=2)
    assert Q.payload_bits(cfg, 1000) == 2064
    assert Q.payload_bits(8, 10) == 144
    adaptive = Q.QuantizerConfig(bits=2, adapt_bits=True)
    assert Q.payload_bits(adaptive, 1000) == 2064
    assert Q.payload_bits(8, 10, adapt_bits=True) == 144
    # per-tensor radius mode bills one f32 radius per tensor
    assert Q.header_bits(num_radii=3) == 32 * 3 + 32
