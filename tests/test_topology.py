"""Topology API contracts: builders, 2-coloring, Koenig edge coloring into
ppermute-able matchings, and the broadcast_dist topology dispatch
(regression: it silently assumed chain ordering)."""
import numpy as np
import pytest

from repro.core import topology as T


@pytest.mark.parametrize("topo_fn,n", [
    (T.chain_topology, 7), (T.chain_topology, 2),
    (T.ring_topology, 8), (T.ring_topology, 2),
    (lambda n: T.star_topology(n, hub=3), 9),
    (lambda n: T.torus2d_topology(4, 4), 16),
    (lambda n: T.torus2d_topology(2, 4), 8),
    (lambda n: T.bipartite_topology(
        6, [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5), (1, 4)]), 6),
])
def test_topology_invariants(topo_fn, n):
    topo = topo_fn(n)
    assert topo.n == n
    # 2-coloring: every edge joins a head (color 0) and a tail, edges are
    # canonically oriented head -> tail
    if topo.num_edges:
        assert (topo.color[topo.edges[:, 0]] == 0).all()
        assert (topo.color[topo.edges[:, 1]] == 1).all()
    # Koenig edge coloring: exactly max-degree colors, every color class a
    # matching, every edge in exactly one class
    assert topo.num_ports == (int(topo.degree.max()) if topo.num_edges else 0)
    seen = set()
    for m in topo.matchings():
        flat = m.ravel().tolist()
        assert len(flat) == len(set(flat)), "color class is not a matching"
        for u, v in m:
            seen.add((min(u, v), max(u, v)))
    assert seen == {(min(u, v), max(u, v)) for u, v in topo.edges}
    # neighbors() and port table agree
    for i in range(n):
        nbrs = set(topo.neighbors(i).tolist())
        assert len(nbrs) == topo.degree[i]
        for u, v in topo.edges:
            if u == i:
                assert v in nbrs
            if v == i:
                assert u in nbrs


def test_rejects_non_bipartite_and_disconnected():
    with pytest.raises(ValueError, match="not bipartite"):
        T.bipartite_topology(3, [(0, 1), (1, 2), (0, 2)])
    with pytest.raises(ValueError, match="not connected"):
        T.bipartite_topology(4, [(0, 1)])
    with pytest.raises(AssertionError):
        T.ring_topology(5)  # odd cycle


def test_star_hub_is_single_head():
    topo = T.star_topology(10, hub=4)
    assert topo.color[4] == 0
    assert topo.head_mask.sum() == 1
    assert topo.degree[4] == 9
    assert (np.delete(topo.degree, 4) == 1).all()


def test_build_topology_dispatch():
    assert T.build_topology("chain", 5).kind == "chain"
    assert T.build_topology("ring", 6).kind == "ring"
    assert T.build_topology("star", 6).kind == "star"
    t = T.build_topology("torus2d", 16)
    assert t.kind == "torus2d" and (t.degree == 4).all()
    got = T.build_topology(t, 16)
    assert got is t
    with pytest.raises(ValueError, match="unknown topology"):
        T.build_topology("hypercube", 8)
    with pytest.raises(AssertionError):
        T.build_topology("torus2d", 6)  # no even x even factorization


# ------------------------------------------------- broadcast_dist dispatch --
def test_broadcast_dist_chain_matches_farther_neighbor():
    """Legacy behavior, re-expressed per worker id: chain position i's
    transmit distance is the farther of its two hop distances."""
    p = T.random_placement(20, seed=3)
    bd = p.broadcast_dist()
    d = p.chain_hop_dist
    expect = np.empty(20)
    expect[0] = d[0]
    expect[-1] = d[-1]
    expect[1:-1] = np.maximum(d[:-1], d[1:])
    # new API is worker-id ordered; chain[j] sits at chain position j
    np.testing.assert_allclose(bd[p.chain], expect)


def test_broadcast_dist_star_hub_uses_farthest_leaf():
    """Regression (satellite): the old implementation assumed chain ordering;
    a star's PS-like hub must bill the distance to its FARTHEST leaf, and
    each leaf exactly its distance to the hub."""
    p = T.random_placement(12, seed=0, topology="star")
    hub = int(np.flatnonzero(p.topology.head_mask)[0])
    assert hub == p.ps_index  # the PS-like min-sum-distance worker
    bd = p.broadcast_dist()
    dists = np.linalg.norm(p.positions - p.positions[hub], axis=1)
    assert bd[hub] == pytest.approx(dists.max())
    for i in range(12):
        if i != hub:
            assert bd[i] == pytest.approx(dists[i])


def test_broadcast_dist_ring_uses_both_cycle_neighbors():
    p = T.random_placement(10, seed=1, topology="ring")
    bd = p.broadcast_dist()
    topo = p.topology
    for i in range(10):
        nbrs = topo.neighbors(i)
        assert len(nbrs) == 2  # a cycle
        expect = max(np.linalg.norm(p.positions[j] - p.positions[i])
                     for j in nbrs)
        assert bd[i] == pytest.approx(expect)


def test_round_energy_topology_censoring_reduces_energy():
    """comm_model: censored workers transmit only the flag bit, so the round
    energy drops strictly; the star hub's share reflects its farthest
    leaf."""
    from repro.core import comm_model as cm

    p = T.random_placement(12, seed=0, topology="star")
    radio = cm.RadioConfig(n_workers=12)
    bits = 4 * 512 + 64
    e_full = cm.round_energy_topology(p, bits, radio)
    sent = np.ones(12, bool)
    sent[::2] = False
    e_cens = cm.round_energy_topology(p, bits, radio, sent=sent)
    e_none = cm.round_energy_topology(p, bits, radio,
                                      sent=np.zeros(12, bool))
    assert 0 < e_none < e_cens < e_full
