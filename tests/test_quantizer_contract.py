"""Cross-implementation contracts of the stochastic quantizer.

core.quantizer (pure jnp, used by the dist trainer), kernels/quantize (fused
Pallas kernel), and the receiver-side dequantize must agree exactly — the
sender==receiver bit-sync is the algorithm's key invariant.  No hypothesis
dependency: these must run in a bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gadmm
from repro.core import quantizer as Q
from repro.kernels.quantize import ops as q_ops


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_core_quantizer_matches_pallas_kernel(bits, dtype):
    """quantize_tensor and the fused kernel (interpret mode) produce identical
    q and theta_hat for shared inputs — same RNG stream, same rounding."""
    key = jax.random.PRNGKey(bits * 7 + (dtype == jnp.bfloat16))
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jax.random.normal(k1, (3, 257)).astype(dtype)
    hat = (0.5 * jax.random.normal(k2, (3, 257))).astype(dtype)
    r = jnp.max(jnp.abs(theta.astype(jnp.float32) - hat.astype(jnp.float32)))
    q_core, hat_core = Q.quantize_tensor(
        theta, hat, k3, radius=r, bits=jnp.asarray(bits, jnp.int32))
    q_pal, hat_pal = q_ops.quantize_dequantize(theta, hat, k3, r, bits,
                                               impl="pallas")
    np.testing.assert_array_equal(np.asarray(q_core), np.asarray(q_pal))
    assert hat_core.dtype == hat_pal.dtype == dtype
    atol = 2e-5 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(hat_core, np.float32),
                               np.asarray(hat_pal, np.float32), atol=atol)


@pytest.mark.parametrize("bits", [2, 8])
def test_zero_radius_contract(bits):
    """Both implementations transmit all-zero q and keep hat unchanged at
    R == 0 (converged worker)."""
    theta = jnp.full((130,), 0.25)
    hat = jnp.full((130,), 0.25)
    r = jnp.zeros(())
    q_core, hat_core = Q.quantize_tensor(
        theta, hat, jax.random.PRNGKey(0), radius=r,
        bits=jnp.asarray(bits, jnp.int32))
    q_pal, hat_pal = q_ops.quantize_dequantize(theta, hat, jax.random.PRNGKey(0),
                                               r, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(q_core), 0)
    np.testing.assert_array_equal(np.asarray(q_pal), 0)
    np.testing.assert_array_equal(np.asarray(hat_core), np.asarray(theta))
    np.testing.assert_array_equal(np.asarray(hat_pal), np.asarray(theta))


@pytest.mark.parametrize("theta_dtype", [jnp.bfloat16, jnp.float32])
def test_mixed_precision_sender_receiver_bit_sync(theta_dtype):
    """Regression: quantize_tensor used to reconstruct in theta.dtype while
    dequantize_tensor used theta_hat_prev.dtype, so a bf16 theta with f32 hat
    state drifted out of bit-sync.  Both now agree on theta_hat_prev.dtype."""
    key = jax.random.PRNGKey(3)
    theta = jax.random.normal(key, (512,)).astype(theta_dtype)
    hat_prev = jnp.zeros((512,), jnp.float32)  # hat state kept in f32
    bits = jnp.asarray(4, jnp.int32)
    for step in range(3):
        r = jnp.max(jnp.abs(theta.astype(jnp.float32) - hat_prev))
        q, hat_sender = Q.quantize_tensor(theta, hat_prev, jax.random.fold_in(
            key, step), radius=r, bits=bits)
        hat_receiver = Q.dequantize_tensor(q, hat_prev, radius=r, bits=bits)
        assert hat_sender.dtype == hat_receiver.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(hat_sender),
                                      np.asarray(hat_receiver))
        hat_prev = hat_sender
        theta = (0.7 * theta.astype(jnp.float32)).astype(theta_dtype)


def test_payload_accounting_unified():
    """quantizer.payload_bits and gadmm.bits_per_round bill the same header:
    32 bits (R) + 32 more only when bits adapt."""
    n, d = 12, 345
    for adapt in (False, True):
        qcfg = Q.QuantizerConfig(bits=4, adapt_bits=adapt)
        gcfg = gadmm.GADMMConfig(quantize=True, qcfg=qcfg)
        assert gadmm.bits_per_round(gcfg, n, d) == n * Q.payload_bits(qcfg, d)
        assert Q.payload_bits(qcfg, d) == 4 * d + Q.header_bits(adapt)


def test_gadmm_adaptive_bits_single_source_of_truth():
    """gadmm._quantize_rows must apply exactly quantizer._next_bits (eq. 11)
    — regression: the bit-growth rule used to be reimplemented inline."""
    n, d = 5, 16
    qcfg = Q.QuantizerConfig(bits=3, adapt_bits=True, max_bits=8)
    cfg = gadmm.GADMMConfig(quantize=True, qcfg=qcfg)
    key = jax.random.PRNGKey(9)
    theta = jax.random.normal(key, (n, d))
    hat_prev = jnp.zeros((n, d))
    r_new = jnp.max(jnp.abs(theta - hat_prev), axis=1)
    # r_prev mixes growth, shrinkage, and the r_prev == 0 first-iteration case
    r_prev = jnp.asarray([0.0, 0.1, 1.0, 5.0, 100.0])
    bits_prev = jnp.asarray([3, 2, 4, 6, 8], jnp.int32)
    active = jnp.ones((n,), bool)
    _, _, b_rows = gadmm._quantize_rows(
        theta, hat_prev, active, jax.random.PRNGKey(0), r_prev, bits_prev, cfg)
    b_rule = Q._next_bits(qcfg, bits_prev, r_new, r_prev)
    np.testing.assert_array_equal(np.asarray(b_rows), np.asarray(b_rule))


def test_topk_selection_is_exact_under_ties():
    """_quantize_rows transmits exactly k coordinates even when |delta| ties
    would admit more (bits_per_round bills exactly k)."""
    n, d = 3, 40
    cfg = gadmm.GADMMConfig(quantize=True,
                            qcfg=Q.QuantizerConfig(bits=8), topk_frac=0.25)
    k = max(int(d * cfg.topk_frac), 1)
    theta = jnp.ones((n, d))  # every |delta| ties at 1.0
    hat_prev = jnp.zeros((n, d))
    active = jnp.ones((n,), bool)
    hat, _, _ = gadmm._quantize_rows(
        theta, hat_prev, active, jax.random.PRNGKey(0),
        jnp.zeros((n,)), jnp.full((n,), 8, jnp.int32), cfg)
    changed = np.asarray(jnp.sum(hat != hat_prev, axis=1))
    np.testing.assert_array_equal(changed, k)
