"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU; asserts output shapes and no NaNs.  Also prefill/decode consistency
for every family's serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import layers as L
from repro.models.config import num_active_params, num_params

ARCHS = registry.ARCHS


def _batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab),
         "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(ks[2], (batch, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[2], (batch, cfg.encoder_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in leaves), arch
    # one SGD step reduces nothing catastrophic (finite loss after update)
    params2 = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = model.loss_fn(params2, batch, cfg)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_forward(arch):
    """Prefill + decode logits == full-sequence forward logits (teacher forcing)."""
    cfg = registry.get_config(arch, smoke=True)
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1), batch=2, seq=12)
    tokens = batch["tokens"]

    # reference: full forward logits at every position
    if cfg.family == "vlm":
        x = model.init.__self__ if False else None
        from repro.models import dense

        h = dense.forward(params, tokens, cfg, extra_embeds=batch["patches"])
        h = h[:, batch["patches"].shape[1]:]
        ref = L.unembed(params["embed"], h, cfg)
    elif cfg.family == "audio":
        from repro.models import encdec

        enc = encdec.encode(params, batch["frames"], cfg)
        h = encdec.decode_train(params, enc, tokens, cfg)
        ref = L.unembed(params["embed"], h, cfg)
    elif cfg.family == "moe":
        h, _ = model.forward(params, tokens, cfg)
        ref = L.unembed(params["embed"], h, cfg)
    else:
        h = model.forward(params, tokens, cfg)
        ref = L.unembed(params["embed"], h, cfg)

    split = 8
    if cfg.family == "audio":
        logits_p, cache = model.prefill(
            params, {"frames": batch["frames"], "tokens": tokens[:, :split]}, cfg)
    elif cfg.family == "vlm":
        full = model.init_cache(cfg, 2, 12)
        logits_p, cache = model.prefill(
            params, {"tokens": tokens[:, :split], "patches": batch["patches"]}, cfg)
    elif cfg.family == "hybrid":
        logits_p, cache = model.prefill(params, tokens[:, :split], cfg, max_seq=12)
    else:
        logits_p, cache = model.prefill(params, tokens[:, :split], cfg)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(ref[:, split - 1]), atol=2e-3, rtol=2e-3)

    # pad caches to full length for families with position-indexed caches
    npatch = cfg.n_patches if cfg.family == "vlm" else 0
    if "k" in cache and cfg.family not in ("hybrid", "ssm"):
        max_seq = 12 + npatch
        pad = max_seq - cache["k"].shape[-3]
        if pad > 0:
            padw = [(0, 0)] * cache["k"].ndim
            padw[-3] = (0, pad)
            cache["k"] = jnp.pad(cache["k"], padw)
            cache["v"] = jnp.pad(cache["v"], padw)

    for i in range(split, 12):
        pos = jnp.full((2,), i + npatch, jnp.int32)
        logits_d, cache = model.decode_step(params, tokens[:, i], cache, pos, cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(ref[:, i]), atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} pos {i}")


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    cfg = registry.get_config(arch)
    n = num_params(cfg)
    expected = {
        "nemotron-4-340b": 340e9, "qwen1.5-32b": 32e9,
        "qwen3-moe-235b-a22b": 235e9, "llava-next-mistral-7b": 7e9,
        "llama4-maverick-400b-a17b": 400e9, "gemma3-27b": 27e9,
        "zamba2-2.7b": 2.7e9, "mamba2-2.7b": 2.7e9,
        "whisper-tiny": 39e6, "qwen1.5-4b": 4e9,
    }[arch]
    assert 0.5 * expected < n < 1.8 * expected, (arch, n, expected)
    na = num_active_params(cfg)
    if cfg.family == "moe":
        assert na < 0.2 * n, (arch, na, n)


@pytest.mark.parametrize("arch", ["gemma3-27b"])
def test_sliding_window_pattern(arch):
    cfg = registry.get_config(arch)
    wins = [cfg.window_for_layer(i) for i in range(12)]
    # 5 local : 1 global
    assert wins[5] == 0 and wins[11] == 0
    assert all(w == 1024 for i, w in enumerate(wins) if (i + 1) % 6 != 0)
    assert cfg.supports_long_context()


def test_long_context_support_flags():
    from repro.models.registry import get_config

    assert get_config("mamba2-2.7b").supports_long_context()
    assert get_config("zamba2-2.7b").supports_long_context()
    assert get_config("gemma3-27b").supports_long_context()
    assert not get_config("qwen1.5-32b").supports_long_context()
    assert not get_config("llama4-maverick-400b-a17b").supports_long_context()
