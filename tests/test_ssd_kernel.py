"""Intra-chunk SSD Pallas kernel: allclose sweeps vs the ref.py oracle and
vs the model's chunked ssd_scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ops

CASES = [
    # (bc, q, h, p, n)
    (1, 8, 1, 4, 4),
    (2, 16, 5, 8, 12),
    (3, 32, 8, 16, 16),
    (1, 64, 3, 64, 128),
]


def _inputs(bc, q, h, p, n, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (bc, q, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bc, q, h))).astype(dtype)
    la = jnp.cumsum(-jnp.abs(jax.random.normal(ks[2], (bc, q, h))) * 0.3,
                    axis=1).astype(dtype)
    b = jax.random.normal(ks[3], (bc, q, n)).astype(dtype)
    c = jax.random.normal(ks[4], (bc, q, n)).astype(dtype)
    return x, dt, la, b, c


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_ref(case, dtype):
    x, dt, la, b, c = _inputs(*case, dtype=dtype)
    yk = ops.ssd_intra(x, dt, la, b, c, impl="pallas")
    yr = ops.ssd_intra(x, dt, la, b, c, impl="ref")
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=atol,
                               rtol=atol)


def test_ssd_kernel_matches_model_scan_single_chunk():
    bc, q, h, p, n = 2, 16, 4, 8, 8
    x, dt, la, b, c = _inputs(bc, q, h, p, n)
    from repro.models.ssm import ssd_scan

    a_log = jnp.zeros((h,))  # A = -1
    la = jnp.cumsum(dt * (-1.0), axis=1)
    y_scan, _ = ssd_scan(x, dt, a_log, b[:, :, None, :], c[:, :, None, :],
                         chunk=q)
    yk = ops.ssd_intra(x, dt, la, b, c)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y_scan), atol=1e-5,
                               rtol=1e-4)


def test_ssd_kernel_head_blocking():
    """Padding the head dim to the block size must not change results."""
    x, dt, la, b, c = _inputs(2, 16, 5, 8, 12)
    from repro.kernels.ssd.ssd import ssd_intra as raw

    y1 = raw(x, dt, la, b, c, head_block=2, interpret=True)
    y2 = raw(x, dt, la, b, c, head_block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
