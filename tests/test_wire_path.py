"""Fused wire-path contracts of the distributed trainer.

Trainer-level parity (wire_impl='jnp' vs 'pallas' bit-identical through a
whole train step — including censored transmissions and non-chain
topologies), the zero-size-leaf regression, and the wire-accounting ==
bytes-on-the-wire invariant (cross-checked against core.comm_model), with
the censored accounting checked against its closed form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import comm_model as cm
from repro.core.censor import FLAG_BITS, CensorConfig
from repro.core.gadmm import GADMMConfig
from repro.core.quantizer import QuantizerConfig
from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
from repro.kernels.pack.ref import packed_len


class MixedModel:
    """Tiny module with a mixed-precision pytree: f32 and bf16 leaves plus a
    zero-size (0,) leaf (regression: _quantize_all used to crash on it)."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wa": jax.random.normal(k1, (6, 4), jnp.float32),
            "wb": (0.1 * jax.random.normal(k2, (4, 3))).astype(jnp.bfloat16),
            "bias": jax.random.normal(k3, (3,), jnp.float32),
            "empty": jnp.zeros((0,), jnp.float32),
        }

    @staticmethod
    def loss_fn(params, batch, cfg):
        h = batch["x"] @ params["wa"]
        h = h @ params["wb"].astype(jnp.float32) + params["bias"]
        return jnp.mean((h.sum(-1) - batch["y"]) ** 2)


def _setup(w=4, **dcfg_kw):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    kw = dict(num_workers=w,
              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                qcfg=QuantizerConfig(bits=4), alpha=0.01),
              local_iters=2, local_lr=1e-2)
    kw.update(dcfg_kw)
    dcfg = DistConfig(**kw)
    tr = QGADMMTrainer(MixedModel, None, dcfg, mesh)
    state = init_state(lambda k: MixedModel.init(k, None),
                       jax.random.PRNGKey(0), dcfg)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (w, 8, 6)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (w, 8))}
    return tr, state, batch


def _run(tr, state, batch, steps=3):
    step = jax.jit(tr.make_train_step())
    for _ in range(steps):
        state, metrics = step(state, batch)
    return state, metrics


@pytest.mark.parametrize("radius_mode", ["global", "per_tensor"])
@pytest.mark.parametrize("pack_wire", [False, True])
def test_trainer_parity_jnp_vs_pallas(radius_mode, pack_wire):
    """A train step with wire_impl='pallas' is bit-identical to 'jnp' on a
    mixed-precision pytree (bf16/f32 leaves), in both radius modes, with and
    without nibble packing — the shared uniform-draw convention at work."""
    tr_j, st_j, batch = _setup(radius_mode=radius_mode, pack_wire=pack_wire,
                               wire_impl="jnp")
    tr_p, st_p, _ = _setup(radius_mode=radius_mode, pack_wire=pack_wire,
                           wire_impl="pallas")
    st_j, m_j = _run(tr_j, st_j, batch)
    st_p, m_p = _run(tr_p, st_p, batch)
    for field in st_j._fields:
        la = jax.tree.leaves(getattr(st_j, field))
        lb = jax.tree.leaves(getattr(st_p, field))
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8) if a.dtype == jnp.bfloat16
                else np.asarray(a),
                np.asarray(b).view(np.uint8) if b.dtype == jnp.bfloat16
                else np.asarray(b),
                err_msg=f"state field {field} diverged")
    np.testing.assert_array_equal(np.asarray(m_j["loss"]),
                                  np.asarray(m_p["loss"]))


def test_jit_train_step_parity_jnp_vs_pallas_sharded():
    """Acceptance: one sharded jit_train_step with wire_impl='pallas' is
    bit-identical to 'jnp' on a mixed-precision pytree, in both radius modes,
    with and without pack_wire (per-shard nibble packing inside the
    exchange's shard_map, uint8 ppermute on the wire)."""
    import os
    import subprocess
    import sys
    import textwrap

    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig

        class MixedModel:
            @staticmethod
            def init(key, cfg):
                k1, k2, k3 = jax.random.split(key, 3)
                return {
                    "wa": jax.random.normal(k1, (8, 4), jnp.float32),
                    "wb": (0.1 * jax.random.normal(k2, (4, 6))
                           ).astype(jnp.bfloat16),
                    "bias": jax.random.normal(k3, (6,), jnp.float32),
                    "empty": jnp.zeros((0,), jnp.float32),
                }

            @staticmethod
            def loss_fn(params, batch, cfg):
                h = batch["x"] @ params["wa"]
                h = h @ params["wb"].astype(jnp.float32) + params["bias"]
                return jnp.mean((h.sum(-1) - batch["y"]) ** 2)

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8)),
                 "y": jax.random.normal(jax.random.PRNGKey(2), (4, 8))}

        def run(wire_impl, radius_mode, pack):
            dcfg = DistConfig(num_workers=4, radius_mode=radius_mode,
                              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                                qcfg=QuantizerConfig(bits=4),
                                                alpha=0.01),
                              local_iters=2, local_lr=1e-2,
                              pack_wire=pack, wire_impl=wire_impl)
            tr = QGADMMTrainer(MixedModel, None, dcfg, wmesh)
            st = init_state(lambda k: MixedModel.init(k, None),
                            jax.random.PRNGKey(0), dcfg)
            st, b = tr.place(st, batch)
            step = tr.jit_train_step(st, b)
            for _ in range(2):
                st, m = step(st, b)
            return st, m

        for radius_mode in ("global", "per_tensor"):
            for pack in (False, True):
                st_j, m_j = run("jnp", radius_mode, pack)
                st_p, m_p = run("pallas", radius_mode, pack)
                for field in st_j._fields:
                    for a, b in zip(jax.tree.leaves(getattr(st_j, field)),
                                    jax.tree.leaves(getattr(st_p, field))):
                        a = np.asarray(jnp.asarray(a, jnp.float32))
                        b = np.asarray(jnp.asarray(b, jnp.float32))
                        np.testing.assert_array_equal(
                            a, b, err_msg=f"{radius_mode} pack={pack} "
                                          f"field {field}")
                assert float(m_j["loss"]) == float(m_p["loss"])
                print("OK", radius_mode, pack)
        print("DONE")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "DONE" in r.stdout


@pytest.mark.parametrize("topology", ["chain", "ring", "star", "torus2d"])
@pytest.mark.parametrize("censored", [False, True])
def test_trainer_parity_topologies_and_censor(topology, censored):
    """wire_impl='pallas' stays bit-identical to 'jnp' on every generalized
    topology, with and without censored transmissions — including the
    censor-flag sideband (skip_rate) and the data-dependent wire accounting."""
    cen = CensorConfig(tau=0.5, xi=0.95) if censored else None
    tr_j, st_j, batch = _setup(topology=topology, censor=cen,
                               wire_impl="jnp")
    tr_p, st_p, _ = _setup(topology=topology, censor=cen,
                           wire_impl="pallas")
    st_j, m_j = _run(tr_j, st_j, batch, steps=4)
    st_p, m_p = _run(tr_p, st_p, batch, steps=4)
    for field in st_j._fields:
        for a, b in zip(jax.tree.leaves(getattr(st_j, field)),
                        jax.tree.leaves(getattr(st_p, field))):
            np.testing.assert_array_equal(
                np.asarray(jnp.asarray(a, jnp.float32)),
                np.asarray(jnp.asarray(b, jnp.float32)),
                err_msg=f"{topology} censored={censored} field {field}")
    for k in ("loss", "skip_rate", "wire_bits_per_round"):
        assert float(m_j[k]) == float(m_p[k]), (topology, censored, k)
    if censored:
        # by step 4 the toy problem's updates are below tau*xi^k: the flag
        # sideband is genuinely exercised
        assert float(m_j["skip_rate"]) > 0.0


def test_unsharded_reference_vs_jit_train_step_censored():
    """The unsharded reference and the sharded jit_train_step agree on a
    censored non-chain topology: the censor-flag sideband (skip_rate) and
    the billed wire bits are IDENTICAL every step, float state agrees to
    partitioned-reduction tolerance (GSPMD reassociates the local matmul
    reductions, so the Adam moments differ in the last ulp)."""
    import os
    import subprocess
    import sys
    import textwrap

    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.censor import CensorConfig
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig

        class MixedModel:
            @staticmethod
            def init(key, cfg):
                k1, k2, k3 = jax.random.split(key, 3)
                return {
                    "wa": jax.random.normal(k1, (8, 4), jnp.float32),
                    "wb": (0.1 * jax.random.normal(k2, (4, 6))
                           ).astype(jnp.bfloat16),
                    "bias": jax.random.normal(k3, (6,), jnp.float32),
                }

            @staticmethod
            def loss_fn(params, batch, cfg):
                h = batch["x"] @ params["wa"]
                h = h @ params["wb"].astype(jnp.float32) + params["bias"]
                return jnp.mean((h.sum(-1) - batch["y"]) ** 2)

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8)),
                 "y": jax.random.normal(jax.random.PRNGKey(2), (4, 8))}

        for topology in ("ring", "star"):
            dcfg = DistConfig(num_workers=4, topology=topology,
                              censor=CensorConfig(tau=0.05, xi=0.9),
                              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                                qcfg=QuantizerConfig(bits=4),
                                                alpha=0.01),
                              local_iters=2, local_lr=1e-2)
            tr = QGADMMTrainer(MixedModel, None, dcfg, wmesh)
            st_u = init_state(lambda k: MixedModel.init(k, None),
                              jax.random.PRNGKey(0), dcfg)
            st_s, b = tr.place(st_u, batch)
            step_s = tr.jit_train_step(st_s, b)
            step_u = jax.jit(tr.make_train_step())
            for it in range(4):
                st_s, m_s = step_s(st_s, b)
                st_u, m_u = step_u(st_u, batch)
                # censor-flag sideband + billed bits: bit-identical
                assert float(m_s["skip_rate"]) == float(m_u["skip_rate"])
                assert (float(m_s["wire_bits_per_round"])
                        == float(m_u["wire_bits_per_round"]))
                for f in st_s._fields:
                    for a, c in zip(jax.tree.leaves(getattr(st_s, f)),
                                    jax.tree.leaves(getattr(st_u, f))):
                        a = np.asarray(jnp.asarray(a, jnp.float32))
                        c = np.asarray(jnp.asarray(c, jnp.float32))
                        np.testing.assert_allclose(
                            a, c, rtol=2e-2, atol=1e-4,
                            err_msg=f"{topology} step {it} field {f}")
            print("OK", topology)
        print("DONE")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "DONE" in r.stdout


def test_sharded_per_tensor_bit_sync_regression():
    """Regression: the sharded codec used to expand per-leaf radii/bits to
    per-position values OUTSIDE its shard_map — a gather whose output is
    sharded along the gathered dimension, which XLA:CPU's SPMD partitioner
    miscompiles inside the fused step.  Senders quantized against garbage
    radii while receivers decoded with the true sideband, so every sharded
    per_tensor (and hence layerwise) run silently desynced by O(radius)
    per step and the consensus residual grew without bound.  The invariant
    that broke: after any number of sharded steps, every stored neighbor
    copy hat_edge[e] tracks the sender's own committed hat[src[e]].

    Tolerance note: bitwise sender==receiver equality is an UNSHARDED-mode
    property.  Sharded mode has always had last-ulp drift in BOTH radius
    modes (the sender's hat comes out of the kernel inside shard_map, the
    receiver's decode is plain jnp under the SPMD jit — XLA fuses the two
    differently), so this asserts a tight tolerance that last-ulp drift
    passes and the old O(radius) garbage fails by orders of magnitude."""
    import os
    import subprocess
    import sys
    import textwrap

    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import LayerwiseConfig, QuantizerConfig

        class MixedModel:
            @staticmethod
            def init(key, cfg):
                k1, k2, k3 = jax.random.split(key, 3)
                return {
                    "wa": jax.random.normal(k1, (8, 4), jnp.float32),
                    "wb": (0.1 * jax.random.normal(k2, (4, 6))
                           ).astype(jnp.bfloat16),
                    "bias": jax.random.normal(k3, (6,), jnp.float32),
                }

            @staticmethod
            def loss_fn(params, batch, cfg):
                h = batch["x"] @ params["wa"]
                h = h @ params["wb"].astype(jnp.float32) + params["bias"]
                return jnp.mean((h.sum(-1) - batch["y"]) ** 2)

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8)),
                 "y": jax.random.normal(jax.random.PRNGKey(2), (4, 8))}

        variants = {
            "per_tensor": dict(radius_mode="per_tensor"),
            "layerwise": dict(layerwise=LayerwiseConfig(
                bits=(4, 2, 3), periods=(1, 2, 1))),
        }
        for name, extra in variants.items():
            dcfg = DistConfig(num_workers=4,
                              gadmm=GADMMConfig(rho=0.5, quantize=True,
                                                qcfg=QuantizerConfig(bits=4),
                                                alpha=0.01),
                              local_iters=2, local_lr=1e-2, **extra)
            tr = QGADMMTrainer(MixedModel, None, dcfg, wmesh)
            st = init_state(lambda k: MixedModel.init(k, None),
                            jax.random.PRNGKey(0), dcfg)
            st, b = tr.place(st, batch)
            step = tr.jit_train_step(st, b)
            for _ in range(4):
                st, m = step(st, b)
            src = np.asarray(tr.eidx.src)
            hat = jax.device_get(st.theta_hat)
            edge = jax.device_get(st.hat_edge)
            for ha, he in zip(jax.tree.leaves(hat), jax.tree.leaves(edge)):
                a = np.asarray(jnp.asarray(ha, jnp.float32))[src]
                e = np.asarray(jnp.asarray(he, jnp.float32))
                np.testing.assert_allclose(
                    a, e, rtol=1e-5, atol=1e-6,
                    err_msg=f"{name}: receiver copy != sender hat")
            print("OK", name)
        print("DONE")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "DONE" in r.stdout


def test_zero_size_leaf_regression():
    """A pytree containing a (0,) leaf must train in both the quantized and
    the full-precision (metrics-radius) branch of phase()."""
    for quantize in (True, False):
        tr, state, batch = _setup(
            gadmm=GADMMConfig(rho=0.5, quantize=quantize,
                              qcfg=QuantizerConfig(bits=4), alpha=0.01))
        state, metrics = _run(tr, state, batch, steps=2)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["radius_mean"]))
        assert state.theta["empty"].shape == (4, 0)


def test_overlap_double_buffered_exchange_trains():
    """overlap=True (tails compute against previous hats while the heads'
    payload is in flight) still decreases the loss."""
    tr, state, batch = _setup(overlap=True)
    step = jax.jit(tr.make_train_step())
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("pack_wire,quantize,radius_mode", [
    (False, True, "global"),
    (True, True, "global"),
    (True, True, "per_tensor"),
    (None, False, "global"),
])
def test_wire_accounting_matches_actual_payload(pack_wire, quantize,
                                                radius_mode):
    """wire_bits_per_round must equal the bytes the ppermute actually moves:
    the constructed wire buffer row (packing + group padding included) plus
    the radius/bits sideband, per link, direction, and phase."""
    tr, state, batch = _setup(
        gadmm=GADMMConfig(rho=0.5, quantize=quantize,
                          qcfg=QuantizerConfig(bits=4), alpha=0.01),
        pack_wire=pack_wire, radius_mode=radius_mode)
    leaves = jax.tree.leaves(state.theta)
    d = sum(int(np.prod(l.shape[1:])) for l in leaves)
    # actual buffer as the exchange moves it: _finish_wire pads the row,
    # then (pack_wire) every device nibble-packs its own shard inside the
    # exchange shard_map
    g = tr._group_size()
    if quantize:
        wire = tr._finish_wire(jnp.zeros((4, d), jnp.uint8))
        if tr.dcfg.pack_wire:
            shard = wire[0].reshape(g, -1)[0]
            from repro.kernels.pack import ops as pack_ops
            actual_row_bytes = g * pack_ops.pack4(shard, impl="ref").size
            assert actual_row_bytes >= packed_len(d)  # per-shard granularity
        else:
            actual_row_bytes = wire.shape[1] * wire.dtype.itemsize
    else:
        wire = tr._flatten_wire(leaves, jnp.float32)
        actual_row_bytes = wire.shape[1] * wire.dtype.itemsize
    assert tr.wire_row_bytes(d) == actual_row_bytes
    n_r = len(leaves) if radius_mode == "per_tensor" else 1
    sideband = (32 * n_r + 32) if quantize else 0
    expected = 2 * 2 * (4 - 1) * (8 * actual_row_bytes + sideband)
    assert tr.wire_bits_per_round(state.theta) == expected
    # the metric reports the same number
    _, metrics = _run(tr, state, batch, steps=1)
    assert int(metrics["wire_bits_per_round"]) == expected


@pytest.mark.parametrize("radius_mode", ["global", "per_tensor"])
def test_core_and_dist_bill_identical_bits(radius_mode):
    """Regression (wire-accounting reconciliation): core's payload_bits /
    header_bits and the dist trainer's wire_bits_per_round now report the
    SAME bits for the same payload in both radius modes — core used to
    elide the 32-bit b sideband when adapt_bits was off, diverging from
    dist by one word per transmission."""
    from repro.core import quantizer as Q

    tr, state, _ = _setup(
        gadmm=GADMMConfig(rho=0.5, quantize=True,
                          qcfg=QuantizerConfig(bits=8), alpha=0.01),
        pack_wire=False, radius_mode=radius_mode)
    leaves = jax.tree.leaves(state.theta)
    d = sum(int(np.prod(l.shape[1:])) for l in leaves)
    # unpacked uint8 wire: one byte per (group-padded) element, so the
    # per-link bits are exactly core's 8-bit payload over d_pad elements
    d_pad = tr.wire_row_bytes(d)
    n_r = len(leaves) if radius_mode == "per_tensor" else 1
    per_link = Q.payload_bits(8, d_pad, num_radii=n_r)
    assert tr.wire_bits_per_round(state.theta) == 2 * 2 * (4 - 1) * per_link
    # and the header rule itself is shared, adapt_bits or not
    assert Q.header_bits(num_radii=n_r) == 32 * n_r + 32
    assert Q.header_bits(adapt_bits=False, num_radii=n_r) == 32 * n_r + 32


@pytest.mark.parametrize("topology", ["chain", "ring"])
def test_censored_wire_accounting_closed_form(topology):
    """The censored wire_bits_per_round metric matches its closed form at
    both extremes: with a vanishing threshold every ACTIVE worker transmits
    (flags + sum_w active*deg payload rows per phase), with a huge one the
    round costs exactly the flag bits (2E per phase)."""
    tiny = CensorConfig(tau=1e-20, xi=0.9)    # transmits whenever hats move
    huge = CensorConfig(tau=1e9, xi=0.999999)  # censors everything
    for cen, expect_kind in ((tiny, "all"), (huge, "none")):
        tr, state, batch = _setup(topology=topology, censor=cen)
        topo = tr.topo
        d = sum(int(np.prod(l.shape[1:]))
                for l in jax.tree.leaves(state.theta))
        per_link = 8 * tr.wire_row_bytes(d) + 32 + 32
        _, metrics = _run(tr, state, batch, steps=1)
        e = topo.num_edges
        heads = topo.head_mask
        deg = topo.degree
        if expect_kind == "all":
            payload = (int(deg[heads].sum()) + int(deg[~heads].sum()))
            expected = 2 * (2 * e * FLAG_BITS) + per_link * payload
            assert float(metrics["skip_rate"]) == 0.0
        else:
            expected = 2 * (2 * e * FLAG_BITS)
            assert float(metrics["skip_rate"]) == 1.0
        assert int(metrics["wire_bits_per_round"]) == expected, (
            topology, expect_kind)
        # and the uncensored baseline of the same trainer is the static form
        assert (tr.wire_bits_per_round(state.theta)
                == 2 * 2 * e * per_link)


def test_wire_accounting_cross_check_comm_model():
    """The Sec. V-A radio model fed with the REPORTED bits must give the
    same transmit energy as when fed with an INDEPENDENTLY measured byte
    count (packing a wire shard by hand), and packing must strictly reduce
    the energy once the payload dominates the pack granularity."""
    from repro.kernels.pack import ops as pack_ops

    radio = cm.RadioConfig(n_workers=4)
    bw = radio.worker_bandwidth(decentralized=True)

    class Big:
        @staticmethod
        def init(key, cfg):
            return {"w": jax.random.normal(key, (64, 64), jnp.float32)}

        loss_fn = None

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    bits = {}
    measured_bits = {}
    for pack in (False, True):
        dcfg = DistConfig(num_workers=4,
                          gadmm=GADMMConfig(quantize=True,
                                            qcfg=QuantizerConfig(bits=4)),
                          pack_wire=pack)
        tr = QGADMMTrainer(Big, None, dcfg, mesh)
        state = init_state(lambda k: Big.init(k, None),
                           jax.random.PRNGKey(0), dcfg)
        bits[pack] = tr.wire_bits_per_round(state.theta)
        # independent measurement: build the padded row, pack a shard the
        # way the exchange does, count bytes + sideband per link/dir/phase
        d = 64 * 64
        row = tr._finish_wire(jnp.zeros((4, d), jnp.uint8))[0]
        g = tr._group_size()
        if pack:
            row_bytes = sum(
                int(pack_ops.pack4(s, impl="ref").size)
                for s in row.reshape(g, -1))
        else:
            row_bytes = int(row.size) * row.dtype.itemsize
        sideband = 32 + 32  # R f32 + b i32 (global radius mode)
        measured_bits[pack] = 2 * 2 * (4 - 1) * (8 * row_bytes + sideband)
    # 4096 params: packed row = 2048 B << unpacked 4096 B
    assert bits[True] < bits[False]
    e_packed = cm.tx_energy(bits[True], 10.0, bw, radio.slot_s,
                            radio.noise_psd)
    e_unpacked = cm.tx_energy(bits[False], 10.0, bw, radio.slot_s,
                              radio.noise_psd)
    assert 0 < e_packed < e_unpacked
    # reported bits == independently measured bits -> the radio model sees
    # the true wire traffic
    for pack in (False, True):
        assert bits[pack] == measured_bits[pack], (pack, bits, measured_bits)
    assert e_packed == cm.tx_energy(measured_bits[True], 10.0, bw,
                                    radio.slot_s, radio.noise_psd)


# ------------------------------------------------ golden bitwise replay ----
def test_golden_state_bitwise():
    """Cross-refactor acceptance: replaying the canonical topology x censor
    x pack matrix reproduces tests/golden/wire_state_v1.npz — captured at
    the pre-refactor (port-dense state) revision — BITWISE: every state
    leaf (neighbor slabs projected to port views), dtype, shape, and wire
    metric, with no keys missing or unaccounted for."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tools"))
    import capture_golden_wire as gw

    with np.load(gw.GOLDEN_PATH) as data:
        golden = {k: data[k] for k in data.files}
    seen = set()
    for topology, censored, pack in gw.golden_cases():
        tag = f"{topology}|c{int(censored)}|p{int(pack)}"
        tr, state, metrics = gw.golden_run(topology, censored, pack)
        for name, arr in gw.state_arrays(tr, state, metrics).items():
            key = f"{tag}|{name}"
            assert key in golden, f"missing golden key {key}"
            assert golden[key].dtype == arr.dtype, key
            assert golden[key].shape == arr.shape, key
            np.testing.assert_array_equal(arr, golden[key], err_msg=key)
            seen.add(key)
    assert seen == set(golden), sorted(set(golden) - seen)[:5]


# --------------------------------------------------- staleness pipeline ----
@pytest.mark.parametrize("topology", ["chain", "star"])
def test_staleness_accounting_closed_form_billed_at_send(topology):
    """Satellite: the staleness-S pipeline bills wire bits on the round the
    payload is SENT, never on the round it is consumed — so the censored
    closed forms hold from round 0 onward, pipeline-fill rounds included
    (a consume-billed scheme would report flag-only rounds while the ring
    fills)."""
    tiny = CensorConfig(tau=1e-20, xi=0.9)
    huge = CensorConfig(tau=1e9, xi=0.999999)
    for cen, expect_kind in ((tiny, "all"), (huge, "none")):
        tr, state, batch = _setup(topology=topology, staleness=2, censor=cen)
        topo = tr.topo
        d = sum(int(np.prod(l.shape[1:]))
                for l in jax.tree.leaves(state.theta))
        per_link = 8 * tr.wire_row_bytes(d) + 32 + 32
        e = topo.num_edges
        deg = topo.degree
        if expect_kind == "all":
            expected = 2 * (2 * e * FLAG_BITS) + per_link * int(deg.sum())
        else:
            expected = 2 * (2 * e * FLAG_BITS)
        step = jax.jit(tr.make_train_step())
        for k in range(3):  # rounds 0 and 1 are pipeline fill at S=2
            state, m = step(state, batch)
            assert int(m["wire_bits_per_round"]) == expected, (
                topology, expect_kind, k)
            assert float(m["skip_rate"]) == (0.0 if expect_kind == "all"
                                             else 1.0), (topology, k)


def test_staleness_accounting_cross_check_sim_per_message():
    """Satellite: the trainer's flag-sideband billing reconciles with
    repro.sim's per-message unicast accounting, round by round.

    The sim (unicast, lossless) charges each transmitting worker per_link
    bits per neighbor and each censored worker FLAG_BITS per neighbor; the
    trainer bills flags on ALL 2E directed links in both phases plus the
    payload per sender degree.  Feeding the sim's recorded sent flags into
    the trainer's accounting, the two differ by exactly the flag bits of
    the silent directed links:

        billed - sim_round == FLAG_BITS * (4E - sum_silent deg)

    and the event timeline's total tx bits equal the per-message model."""
    from repro.sim.network import ComputeModel, NetworkConfig
    from repro.sim.runner import (SimConfig, simulate_trainer,
                                  trainer_link_bits)

    rounds = 6
    tr, state, batch = _setup(topology="chain",
                              censor=CensorConfig(tau=0.5, xi=0.95))
    topo = tr.topo
    d = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(state.theta))
    per_link = trainer_link_bits(tr, d)
    scfg = SimConfig(topology="chain", rounds=rounds, staleness=2, seed=0,
                     network=NetworkConfig(transport="unicast",
                                           latency_s=1e-3),
                     compute=ComputeModel(base_s=1e-3))
    res = simulate_trainer(tr, state, batch, scfg)
    heads = np.asarray(topo.head_mask)
    deg = np.asarray(topo.degree)
    e = topo.num_edges
    model_total = 0.0
    for k in range(rounds):
        sent = np.array([bool(res.states[k][w]["sent"]) for w in range(4)])
        billed = float(tr.wire_bits_per_round(
            state.theta, [jnp.asarray(sent & heads),
                          jnp.asarray(sent & ~heads)]))
        sim_round = (per_link * float(deg[sent].sum())
                     + FLAG_BITS * float(deg[~sent].sum()))
        model_total += sim_round
        assert billed - sim_round == FLAG_BITS * (
            4 * e - float(deg[~sent].sum())), k
    assert sum(t.bits for t in res.timeline.tx) == model_total
    assert any(not res.states[k][w]["sent"]
               for k in range(rounds) for w in range(4)), \
        "censor never fired: the cross-check only exercised the all-sent row"


def test_staleness2_trainer_matches_sim_async_objective():
    """Acceptance: a DistConfig.staleness=2 trainer run matches the
    corresponding repro.sim async (SimConfig.staleness=2) run within 1e-3
    relative objective gap.  Both integrate the round-(k-S) dual residual
    (trainer: hat_lag pipeline; sim: common-round lag histories), so they
    share the consensus fixed point; the damped alpha keeps the S-delayed
    dual iteration stable and the quantization noise ball contracts as the
    hats converge."""
    from repro.sim.network import ComputeModel, NetworkConfig
    from repro.sim.runner import SimConfig, simulate_trainer

    class LinReg:
        @staticmethod
        def init(key, cfg):
            return {"w": 0.01 * jax.random.normal(key, (8,)),
                    "b": jnp.zeros(())}

        @staticmethod
        def loss_fn(params, batch, cfg):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean((pred - batch["y"]) ** 2)

    w = 4
    steps = 150
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=8)
    x = rng.normal(size=(w, 32, 8))
    y = x @ w_true + 0.1 * rng.normal(size=(w, 32))
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    xf, yf = jnp.asarray(x.reshape(-1, 8)), jnp.asarray(y.reshape(-1))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("worker", "fsdp", "model"))
    gcfg = GADMMConfig(rho=0.1, quantize=True,
                       qcfg=QuantizerConfig(bits=4), alpha=0.1)

    def objective(theta):
        wbar, bbar = jnp.mean(theta["w"], axis=0), jnp.mean(theta["b"])
        return float(jnp.mean((xf @ wbar + bbar - yf) ** 2))

    dcfg = DistConfig(num_workers=w, topology="chain", staleness=2,
                      gadmm=gcfg, local_iters=5, local_lr=5e-2)
    tr = QGADMMTrainer(LinReg, None, dcfg, mesh)
    st = init_state(lambda k: LinReg.init(k, None), jax.random.PRNGKey(0),
                    dcfg)
    step = jax.jit(tr.make_train_step())
    for _ in range(steps):
        st, _ = step(st, batch)
    o_trainer = objective(st.theta)

    dcfg0 = DistConfig(num_workers=w, topology="chain", gadmm=gcfg,
                       local_iters=5, local_lr=5e-2)
    tr0 = QGADMMTrainer(LinReg, None, dcfg0, mesh)
    st0 = init_state(lambda k: LinReg.init(k, None), jax.random.PRNGKey(0),
                     dcfg0)
    scfg = SimConfig(topology="chain", rounds=steps, staleness=2, seed=0,
                     network=NetworkConfig(latency_s=1e-3, jitter_s=1e-3),
                     compute=ComputeModel(base_s=1e-3, straggler={1: 4.0}))
    res = simulate_trainer(tr0, st0, batch, scfg)
    last = res.states[-1]
    theta_sim = {k: jnp.asarray(np.stack(
        [np.asarray(last[i]["theta"][k]) for i in range(w)]))
        for k in ("w", "b")}
    o_sim = objective(theta_sim)
    rel_gap = abs(o_trainer - o_sim) / max(abs(o_sim), 1e-12)
    assert rel_gap < 1e-3, (o_trainer, o_sim, rel_gap)


# ------------------------------------------------- degenerate graphs -------
def test_single_worker_degenerate_trains():
    """W=1 (no edges): the trainer must run the no-exchange path — zero
    wire traffic, zero consensus residual, finite loss — and staleness>0
    must fall back to the barriered step (a 1-worker pipeline has nothing
    in flight)."""
    for staleness in (0, 1):
        tr, state, batch = _setup(w=1, staleness=staleness)
        assert tr.topo.num_edges == 0
        state, m = _run(tr, state, batch, steps=2)
        assert np.isfinite(float(m["loss"]))
        assert float(m["wire_bits_per_round"]) == 0.0
        assert float(m["consensus_resid"]) == 0.0


# --------------------------------------------- partial participation -------
def test_trainer_partial_participation_listen_only():
    """DistConfig.participation < 1 draws a shared per-round worker mask
    from a fold-in stream: absent workers skip compute/transmit (fewer
    billed wire bits) but still fold received hats through
    degree-renormalized port weights, so the objective keeps decreasing.
    An explicit participation=1.0 must take the untouched default path
    bit-for-bit (the gate never fires, the key stream is unperturbed)."""
    w, steps = 6, 12
    tr_f, st_f, batch = _setup(w=w, topology="ring")
    tr_p, st_p, _ = _setup(w=w, topology="ring", participation=0.5)
    step_f = jax.jit(tr_f.make_train_step())
    step_p = jax.jit(tr_p.make_train_step())
    bits_f, bits_p, losses = [], [], []
    for _ in range(steps):
        st_f, m_f = step_f(st_f, batch)
        st_p, m_p = step_p(st_p, batch)
        bits_f.append(float(m_f["wire_bits_per_round"]))
        bits_p.append(float(m_p["wire_bits_per_round"]))
        losses.append(float(m_p["loss"]))
    assert np.mean(bits_p) < 0.7 * np.mean(bits_f), (bits_p, bits_f)
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0], losses

    tr_1, st_1, _ = _setup(w=w, topology="ring", participation=1.0)
    tr_d, st_d, _ = _setup(w=w, topology="ring")
    st_1, m_1 = _run(tr_1, st_1, batch, steps=2)
    st_d, m_d = _run(tr_d, st_d, batch, steps=2)
    for a, b in zip(jax.tree.leaves(st_1.theta), jax.tree.leaves(st_d.theta)):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8) if a.dtype == jnp.bfloat16
            else np.asarray(a),
            np.asarray(b).view(np.uint8) if b.dtype == jnp.bfloat16
            else np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m_1["loss"]),
                                  np.asarray(m_d["loss"]))


def test_trainer_participation_composes_and_validates():
    """participation composes with censoring and bounded staleness without
    NaNs, and the config rejects rates outside (0, 1]."""
    for kw in ({"censor": CensorConfig(tau=0.05, xi=0.9)}, {"staleness": 1}):
        tr, state, batch = _setup(w=4, topology="ring", participation=0.5,
                                  **kw)
        state, m = _run(tr, state, batch, steps=4)
        assert np.isfinite(float(m["loss"])), kw
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(AssertionError):
            DistConfig(num_workers=4, gadmm=GADMMConfig(), participation=bad)
