"""Dry-run matrix definition tests (no compilation: specs + skip policy)."""
import subprocess
import sys
import os
import textwrap

import pytest


def test_matrix_is_40_minus_documented_skips():
    """10 archs x 4 shapes = 40; long_500k runs only for the 3 sub-quadratic
    archs (DESIGN.md) -> 33 dry-run pairs."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import iter_pairs, LONG_OK, SHAPES
        from repro.models import registry
        pairs = list(iter_pairs())
        assert len(pairs) == 33, len(pairs)
        assert len(registry.ARCHS) * len(SHAPES) == 40
        longs = [a for a, s in pairs if s == "long_500k"]
        assert sorted(longs) == sorted(LONG_OK)
        # every long-context arch actually supports it per its config
        for a in LONG_OK:
            assert registry.get_config(a).supports_long_context(), a
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_input_specs_cover_every_family_and_shape():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import input_specs, SHAPES
        from repro.models import registry
        for arch in registry.ARCHS:
            cfg = registry.get_config(arch)
            for shape, sh in SHAPES.items():
                if sh["kind"] == "train":
                    b = input_specs(cfg, shape, num_workers=4)
                    assert b["tokens"].shape == (4, sh["batch"] // 4, sh["seq"])
                    assert b["labels"].shape == b["tokens"].shape
                    if cfg.family == "vlm":
                        assert b["patches"].shape[-2:] == (cfg.n_patches,
                                                           cfg.d_model)
                    if cfg.family == "audio":
                        assert b["frames"].shape[-2:] == (cfg.encoder_frames,
                                                          cfg.d_model)
                elif sh["kind"] == "prefill":
                    b = input_specs(cfg, shape)
                    assert b["tokens"].shape == (sh["batch"], sh["seq"])
                else:
                    b = input_specs(cfg, shape)
                    assert b["token"].shape == (sh["batch"],)
                    assert b["pos"].shape == (sh["batch"],)
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_recorded_dryrun_artifacts_are_complete():
    """If the sweep artifacts exist in the repo root, they must be 33/33."""
    import json

    root = os.path.join(os.path.dirname(__file__), "..")
    for name in ("dryrun_singlepod.json", "dryrun_multipod.json",
                 "dryrun_singlepod_opt.json", "dryrun_multipod_opt.json"):
        path = os.path.join(root, name)
        if not os.path.exists(path):
            pytest.skip(f"{name} not generated yet")
        rows = json.load(open(path))
        ok = [r for r in rows if "error" not in r]
        assert len(ok) == 33, (name, len(ok))
