"""Dry-run matrix definition tests (no compilation: specs + skip policy)."""
import subprocess
import sys
import os
import textwrap

import pytest


def test_matrix_is_40_minus_documented_skips():
    """10 archs x 4 shapes = 40; long_500k runs only for the 3 sub-quadratic
    archs (DESIGN.md) -> 33 dry-run pairs."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import iter_pairs, LONG_OK, SHAPES
        from repro.models import registry
        pairs = list(iter_pairs())
        assert len(pairs) == 33, len(pairs)
        assert len(registry.ARCHS) * len(SHAPES) == 40
        longs = [a for a, s in pairs if s == "long_500k"]
        assert sorted(longs) == sorted(LONG_OK)
        # every long-context arch actually supports it per its config
        for a in LONG_OK:
            assert registry.get_config(a).supports_long_context(), a
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_input_specs_cover_every_family_and_shape():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import input_specs, SHAPES
        from repro.models import registry
        for arch in registry.ARCHS:
            cfg = registry.get_config(arch)
            for shape, sh in SHAPES.items():
                if sh["kind"] == "train":
                    b = input_specs(cfg, shape, num_workers=4)
                    assert b["tokens"].shape == (4, sh["batch"] // 4, sh["seq"])
                    assert b["labels"].shape == b["tokens"].shape
                    if cfg.family == "vlm":
                        assert b["patches"].shape[-2:] == (cfg.n_patches,
                                                           cfg.d_model)
                    if cfg.family == "audio":
                        assert b["frames"].shape[-2:] == (cfg.encoder_frames,
                                                          cfg.d_model)
                elif sh["kind"] == "prefill":
                    b = input_specs(cfg, shape)
                    assert b["tokens"].shape == (sh["batch"], sh["seq"])
                else:
                    b = input_specs(cfg, shape)
                    assert b["token"].shape == (sh["batch"],)
                    assert b["pos"].shape == (sh["batch"],)
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_topology_and_censor_axes_in_cli_matrix():
    """The documented sweep matrix covers the new --topology / --censor
    axes (with their threshold knobs), wired through to DistConfig."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch import dryrun
        from repro.core.topology import TOPOLOGY_KINDS
        import argparse, inspect

        # CLI exposes every topology kind plus the censor knobs
        ap_actions = {}
        import repro.launch.dryrun as d
        # build the parser exactly as main() does by introspecting main's
        # argparse calls: simplest is to run --help through a parse probe
        import contextlib, io
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            try:
                d.main(["--help"])
            except SystemExit:
                pass
        text = buf.getvalue()
        for flag in ("--topology", "--censor", "--censor-tau", "--censor-xi"):
            assert flag in text, flag
        for kind in TOPOLOGY_KINDS:
            assert kind in text, kind
        # and dryrun_train threads them into DistConfig
        sig = inspect.signature(d.dryrun_train)
        assert "topology" in sig.parameters
        assert "censor" in sig.parameters
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_boolean_flags_have_working_negatives():
    """Regression: several launchers declared store_true flags with
    default=True — the positive spelling was a silent no-op and the negative
    pair was hand-rolled (or missing: simulate's --x64/--no-x64 were two
    independent store_trues).  BooleanOptionalAction generates both
    spellings; the help text is the observable contract."""
    code = """
        import contextlib, io

        def help_text(main):
            buf = io.StringIO()
            with contextlib.redirect_stdout(buf):
                try:
                    main(["--help"])
                except SystemExit:
                    pass
            return buf.getvalue()

        from repro.launch import dryrun, serve, simulate, train
        t = help_text(simulate.main)
        assert "--x64" in t and "--no-x64" in t, t[-500:]
        assert "--record-states" in t and "--no-record-states" in t
        t = help_text(dryrun.main)
        for flag in ("--attn-remat", "--no-attn-remat", "--uneven",
                     "--no-uneven", "--pack", "--no-pack",
                     "--windowed-cache", "--no-windowed-cache",
                     "--layerwise", "--layerwise-period", "--bit-budget"):
            assert flag in t, flag
        t = help_text(serve.main)
        assert "--no-smoke" in t and "--full" in t  # --full kept working
        t = help_text(train.main)
        for flag in ("--layerwise", "--layerwise-period", "--bit-budget"):
            assert flag in t, flag
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=240, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_reduced_smoke_compile_layerwise():
    """One reduced train pair compiles end-to-end with the layerwise
    (L-FGADMM) wire — the --layerwise / --bit-budget sweep axis is
    CPU-recordable like the other committed artifacts."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.core.quantizer import LayerwiseConfig
        from repro.launch.dryrun import dryrun_train
        r = dryrun_train("qwen1.5-4b", "train_4k", multi_pod=False,
                         workers=8, reduced=True, bits=4,
                         layerwise=LayerwiseConfig(large_leaf_period=2,
                                                   budget_bits=2_000_000),
                         verbose=False)
        assert r["layerwise"] is True
        assert r["collective_bytes_per_device"] > 0
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=540, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_reduced_smoke_compile_topology_censor():
    """One reduced (16-device smoke mesh) train pair compiles end-to-end on
    a censored ring topology — the new sweep axes are CPU-recordable just
    like the committed dryrun_*.json artifacts."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.core.censor import CensorConfig
        from repro.launch.dryrun import dryrun_train
        r = dryrun_train("qwen1.5-4b", "train_4k", multi_pod=False,
                         workers=8, reduced=True, bits=4, topology="ring",
                         censor=CensorConfig(tau=0.05, xi=0.9),
                         verbose=False)
        assert r["topology"] == "ring" and r["censor"] is True
        assert r["collective_bytes_per_device"] > 0
        assert r["collective_counts"].get("collective-permute", 0) > 0
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=540, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_recorded_dryrun_artifacts_are_complete():
    """If the sweep artifacts exist in the repo root, they must be 33/33."""
    import json

    root = os.path.join(os.path.dirname(__file__), "..")
    for name in ("dryrun_singlepod.json", "dryrun_multipod.json",
                 "dryrun_singlepod_opt.json", "dryrun_multipod_opt.json"):
        path = os.path.join(root, name)
        if not os.path.exists(path):
            pytest.skip(f"{name} not generated yet")
        rows = json.load(open(path))
        ok = [r for r in rows if "error" not in r]
        assert len(ok) == 33, (name, len(ok))
