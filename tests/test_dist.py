"""Distributed runtime tests (subprocesses with forced host device counts —
the main pytest process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_qgadmm_dist_loss_decreases_and_uint8_wire():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, re
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig
        from repro.models import registry

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        cfg = registry.get_config("qwen1.5-4b", smoke=True)
        model = registry.get_model(cfg)
        dcfg = DistConfig(num_workers=4,
                          gadmm=GADMMConfig(rho=0.5, quantize=True,
                                            qcfg=QuantizerConfig(bits=8),
                                            alpha=0.01),
                          local_iters=2, local_lr=2e-3)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 2, 32), 0, cfg.vocab)}
        state, batch = tr.place(state, batch)
        step = tr.jit_train_step(state, batch)
        losses = []
        for i in range(12):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses
        txt = step.lower(state, batch).compile().as_text()
        u8 = [l for l in txt.splitlines() if "collective-permute" in l and "u8[" in l]
        assert len(u8) > 0, "quantized exchange must be uint8 collective-permute"
        print("OK", losses[0], losses[-1], len(u8))
    """)
    assert "OK" in out


def test_fsdp_degenerate_mode_w1():
    """num_workers=1 == plain FSDP data parallel: no chain collectives."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig
        from repro.models import registry

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=1)
        cfg = registry.get_config("qwen1.5-4b", smoke=True)
        model = registry.get_model(cfg)
        dcfg = DistConfig(num_workers=1,
                          gadmm=GADMMConfig(rho=0.5, quantize=False),
                          local_iters=1, local_lr=2e-3)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (1, 8, 32), 0, cfg.vocab)}
        state, batch = tr.place(state, batch)
        step = tr.jit_train_step(state, batch)
        l0 = None
        for i in range(8):
            state, m = step(state, batch)
            l0 = l0 or float(m["loss"])
        assert float(m["loss"]) < l0
        print("OK")
    """)
    assert "OK" in out


def test_jacobi_mode_runs():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig
        from repro.models import registry

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        cfg = registry.get_config("mamba2-2.7b", smoke=True)
        model = registry.get_model(cfg)
        dcfg = DistConfig(num_workers=4, mode="jacobi",
                          gadmm=GADMMConfig(rho=0.5, quantize=True,
                                            qcfg=QuantizerConfig(bits=8),
                                            alpha=0.01),
                          local_iters=1, local_lr=2e-3)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 2, 32), 0, cfg.vocab)}
        state, batch = tr.place(state, batch)
        step = tr.jit_train_step(state, batch)
        losses = []
        for i in range(10):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("OK")
    """)
    assert "OK" in out


def test_dist_matches_single_process_reference():
    """2-worker distributed chain == sequential reference on the same data
    (unquantized GADMM, deterministic)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.models import registry, mlp

        # tiny dense model via the registry smoke config
        cfg = registry.get_config("qwen1.5-4b", smoke=True)
        model = registry.get_model(cfg)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
        wmesh = factor_mesh(mesh, num_workers=2)
        dcfg = DistConfig(num_workers=2,
                          gadmm=GADMMConfig(rho=0.3, quantize=False, alpha=0.01),
                          local_iters=1, local_lr=1e-2)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 2, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 2, 16), 0, cfg.vocab)}
        st, b = tr.place(state, batch)
        step = tr.jit_train_step(st, b)
        for _ in range(3):
            st, m = step(st, b)
        dist_loss = float(m["loss"])

        # sequential reference: same step function, no sharding (1 device ok)
        st2 = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        step2 = tr.make_train_step()
        for _ in range(3):
            st2, m2 = step2(st2, batch)
        ref_loss = float(m2["loss"])
        assert abs(dist_loss - ref_loss) < 2e-2, (dist_loss, ref_loss)
        print("OK", dist_loss, ref_loss)
    """)
    assert "OK" in out


def test_dryrun_mini_mesh():
    """dryrun module end-to-end on a small subset mesh (8 of 512 devices)."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import dryrun_train, dryrun_serve
        r = dryrun_train("qwen1.5-4b", "train_4k", multi_pod=False, workers=16,
                         verbose=False)
        assert r["collective_bytes_per_device"] > 0
        assert r["hlo_flops_per_device"] > 0
        assert "dominant" in r
        r2 = dryrun_serve("mamba2-2.7b", "decode_32k", multi_pod=False,
                          verbose=False)
        assert r2["hlo_flops_per_device"] > 0
        print("OK")
    """, devices=512, timeout=560)
    assert "OK" in out


def test_checkpoint_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.train import checkpoint

    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    checkpoint.save(str(tmp_path), 7, tree, metadata={"arch": "x"})
    assert checkpoint.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    back = checkpoint.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_mismatch_errors(tmp_path):
    """Regression: restore used to mis-assign arrays (or die deep inside an
    np cast) when `like` didn't match the checkpoint; it must instead raise
    a ValueError naming the offending leaf / structure difference."""
    import jax
    import jax.numpy as jnp
    import pytest as _pytest

    from repro.train import checkpoint

    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,))}
    checkpoint.save(str(tmp_path), 1, tree)
    # leaf count mismatch
    with _pytest.raises(ValueError, match="leaves"):
        checkpoint.restore(str(tmp_path), 1, {"a": tree["a"]})
    # per-leaf shape mismatch, error names the leaf path
    bad_shape = {"a": jnp.zeros((3, 2)), "b": tree["b"]}
    with _pytest.raises(ValueError, match=r"\['a'\].*shape"):
        checkpoint.restore(str(tmp_path), 1, bad_shape)
    # same structure arity but different tree paths (sidecar names check)
    renamed = {"a": tree["a"], "z": tree["b"]}
    with _pytest.raises(ValueError, match="tree paths"):
        checkpoint.restore(str(tmp_path), 1, renamed)
    # matching `like` still restores
    back = checkpoint.restore(str(tmp_path), 1,
                              jax.tree.map(jnp.zeros_like, tree))
    assert jax.tree.structure(back) == jax.tree.structure(tree)


def test_data_pipeline_shapes():
    from repro.data.pipeline import LMShardLoader

    ld = LMShardLoader(n_workers=3, per_worker_batch=2, seq_len=16, vocab=101)
    b = ld.next_batch()
    assert b["tokens"].shape == (3, 2, 16)
    assert b["labels"].shape == (3, 2, 16)
    assert (b["tokens"] < 101).all() and (b["tokens"] >= 0).all()
    # labels are next-token shifted
    import numpy as np
    assert not np.array_equal(b["tokens"], b["labels"])


def test_per_tensor_radius_mode_trains():
    """Beyond-paper: per-tensor quantization ranges (tighter than global R)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.mesh import factor_mesh
        from repro.dist.qgadmm import DistConfig, QGADMMTrainer, init_state
        from repro.core.gadmm import GADMMConfig
        from repro.core.quantizer import QuantizerConfig
        from repro.models import registry

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        wmesh = factor_mesh(mesh, num_workers=4)
        cfg = registry.get_config("qwen1.5-4b", smoke=True)
        model = registry.get_model(cfg)
        dcfg = DistConfig(num_workers=4, radius_mode="per_tensor",
                          gadmm=GADMMConfig(rho=0.5, quantize=True,
                                            qcfg=QuantizerConfig(bits=4),
                                            alpha=0.01),
                          local_iters=2, local_lr=2e-3, pack_wire=True)
        tr = QGADMMTrainer(model, cfg, dcfg, wmesh)
        state = init_state(lambda k: model.init(k, cfg), jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 2, 32), 0, cfg.vocab)}
        state, batch = tr.place(state, batch)
        step = tr.jit_train_step(state, batch)
        losses = []
        for i in range(10):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2, losses
        print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_server_prefill_decode_sharded():
    """Server prefill + decode on an emulated mesh: logits stay batch-sharded,
    caches stay sharded, decode step output matches single-device reference."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.dist.serve import Server, serve_view
        from repro.models import registry

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        smesh = serve_view(mesh)
        cfg = registry.get_config("qwen1.5-4b", smoke=True)
        model = registry.get_model(cfg)
        server = Server(model=model, cfg=cfg, mesh=smesh, batch_size=4)
        params = model.init(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
        batch = {"tokens": tokens}
        pf = server.jit_prefill(params, batch, 4)
        logits, cache = pf(params, batch)
        assert logits.shape == (4, cfg.vocab)
        # reference (no sharding)
        ref_logits, ref_cache = model.prefill(params, tokens, cfg)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                                   atol=2e-3, rtol=2e-3)
        # decode one step
        cache = jax.tree.map(lambda a: jnp.pad(
            a, [(0, 0)] * (a.ndim - 3) + [(0, 4), (0, 0), (0, 0)]), cache)
        dec = server.jit_decode(params, cache, 4)
        tok = jnp.argmax(logits, axis=-1)
        pos = jnp.full((4,), 8, jnp.int32)
        logits2, cache2 = dec(params, tok, cache, pos)
        assert logits2.shape == (4, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all()
        print("OK")
    """)
    assert "OK" in out
