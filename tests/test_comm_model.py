"""Wireless comm/energy model tests (paper Sec. V-A accounting)."""
import os

import numpy as np
import pytest

if os.environ.get("REPRO_CI") == "1":
    import hypothesis  # noqa: F401  CI promises the property suites: hard fail
else:
    pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import comm_model as cm
from repro.core.topology import random_placement


def test_energy_monotone_in_bits_and_distance():
    e1 = cm.tx_energy(1000, 50, 40e3, 1e-3, 1e-6)
    e2 = cm.tx_energy(2000, 50, 40e3, 1e-3, 1e-6)
    e3 = cm.tx_energy(1000, 100, 40e3, 1e-3, 1e-6)
    assert e2 > e1 and e3 > e1
    assert e3 == pytest.approx(4 * e1)  # free-space D^2


def test_bandwidth_split_decentralized_vs_ps():
    radio = cm.RadioConfig(total_bandwidth_hz=2e6, n_workers=50)
    assert radio.worker_bandwidth(True) == pytest.approx(2 * 2e6 / 50)
    assert radio.worker_bandwidth(False) == pytest.approx(2e6 / 50)


def test_decentralized_cheaper_than_ps_for_same_bits():
    """Neighbors are closer than the PS on average -> chain round cheaper."""
    p = random_placement(50, seed=0)
    radio = cm.RadioConfig(n_workers=50)
    bits = 192.0
    e_chain = cm.round_energy_decentralized(np.full(50, bits),
                                            p.broadcast_dist(), radio)
    e_ps = cm.round_energy_ps(bits, p.ps_dist, bits, radio)
    assert e_chain < e_ps


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=4, max_value=64),
       st.integers(min_value=0, max_value=10**6))
def test_placement_invariants(n, seed):
    p = random_placement(n, seed=seed)
    assert sorted(p.chain.tolist()) == list(range(n))
    assert (p.chain_hop_dist >= 0).all()
    assert 0 <= p.ps_index < n
    assert p.ps_dist[p.ps_index] == 0
    bd = p.broadcast_dist()
    # worker-id order (topology-dispatched): the chain endpoints' transmit
    # distance is their single hop; interior workers take the farther hop
    assert bd[p.chain[0]] == pytest.approx(p.chain_hop_dist[0])
    assert bd[p.chain[-1]] == pytest.approx(p.chain_hop_dist[-1])
    hops = np.maximum(p.chain_hop_dist[:-1], p.chain_hop_dist[1:])
    np.testing.assert_allclose(bd[p.chain[1:-1]], hops)
